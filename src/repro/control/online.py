"""Online cap profiler — FROST without dedicated probe windows.

The batch ``CapProfiler`` freezes the pipeline for 8 x ~30 s probes.  Under
production traffic (ROADMAP north star) that is a service interruption, so
this profiler *amortises* the probes across live work instead:

  * every ``StepDone`` event is attributed to the cap that was in force
    (bucketed onto the probe grid), accumulating decayed (energy, delay,
    samples) sums per cap — the same ``CapMeasurement`` shape the batch
    profiler produces, built incrementally from streamed telemetry;
  * an initial *sweep* visits each legal grid cap for ``steps_per_probe``
    live steps (a few seconds of traffic, not 4 minutes of probe windows),
    then fits F(x) (paper Eqs 6-7) and applies the ED^mP-optimal cap via
    :func:`repro.core.profiler.decide_cap` — the identical decision rule;
  * afterwards it *holds* the chosen cap, refreshing ONE grid cap per
    ``hold_steps`` window (round-robin) so the fit tracks the workload with
    bounded overhead — the 8-point probe cost is spread over 8 hold cycles;
  * drift detection runs continuously: when the observed time/sample departs
    from the fit's expectation by more than ``drift_threshold``, it publishes
    ``DriftDetected`` and restarts the sweep (workload changed under us);
  * warm starts: pass a cached ``CapDecision`` (e.g. from a previous batch
    profile or a prior run) to skip the sweep entirely and go straight to
    hold — probes then only ever run as amortised refreshes.

Everything is driven by bus events; the profiler never blocks the pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

from repro.control.bus import EventBus
from repro.control.events import (CapApplied, DriftDetected, FitUpdated,
                                  PolicyUpdated, PowerSampled, StepDone)
from repro.core.edp import CapMeasurement
from repro.core.policy import QoSPolicy
from repro.core.profiler import (DEFAULT_CAP_GRID, CapBackend, CapDecision,
                                 interp_measurements, decide_cap)


@dataclasses.dataclass
class _CapBucket:
    """Decayed (energy, delay, samples) sums for one grid cap."""
    energy_j: float = 0.0
    delay_s: float = 0.0
    samples: float = 0.0

    def add(self, energy_j: float, delay_s: float, samples: float,
            decay: float) -> None:
        self.energy_j = self.energy_j * decay + energy_j
        self.delay_s = self.delay_s * decay + delay_s
        self.samples = self.samples * decay + samples

    def measurement(self, cap: float) -> CapMeasurement:
        return CapMeasurement(cap=cap, energy_j=self.energy_j,
                              delay_s=self.delay_s, samples=self.samples)


class OnlineCapProfiler:
    """Event-driven profiler: subscribe, stream, retune.

    Modes: ``sweep`` (initial grid coverage) -> ``hold`` (optimal cap in
    force) -> ``refresh`` (one amortised probe cap) -> ``hold`` -> ...
    plus ``waiting`` (no energy telemetry: parked at the highest legal cap
    until usable samples arrive — never throttle on blind data).
    """

    def __init__(
        self,
        bus: EventBus,
        backend: CapBackend,
        *,
        policy: QoSPolicy | None = None,
        node_id: str = "node-0",
        model_id: str = "",
        cap_grid: Sequence[float] = DEFAULT_CAP_GRID,
        steps_per_probe: int = 2,
        hold_steps: int = 32,
        decay: float = 0.6,
        drift_threshold: float = 0.15,
        drift_min_steps: int = 3,
        switch_margin: float = 0.02,
        min_refresh_interval_s: float = 5.0,
        clock: Callable[[], float] = time.monotonic,
        warm_start: CapDecision | None = None,
        on_decision: Callable[[CapDecision], None] | None = None,
    ) -> None:
        self.bus = bus
        self.backend = backend
        self.policy = policy or QoSPolicy()
        self.node_id = node_id
        self.model_id = model_id
        self.steps_per_probe = int(steps_per_probe)
        self.hold_steps = int(hold_steps)
        self.decay = float(decay)
        self.drift_threshold = float(drift_threshold)
        self.drift_min_steps = int(drift_min_steps)
        self.switch_margin = float(switch_margin)
        self.min_refresh_interval_s = float(min_refresh_interval_s)
        self._clock = clock
        self._last_refit_t = -float("inf")
        self.on_decision = on_decision

        self._full_grid = tuple(sorted(float(c) for c in cap_grid))
        self._grid = self._legal_grid()
        self._buckets: dict[float, _CapBucket] = {}
        self.decision: CapDecision | None = None
        self.mode = "sweep"
        self.n_steps = 0
        self.n_refits = 0
        self.n_cap_changes = 0
        self._probe_idx = 0
        self._refresh_idx = 0
        self._steps_in_state = 0
        self._last_watts = 0.0
        self._no_energy_steps = 0
        self._obs_time_ewma: float | None = None
        self._obs_count = 0
        self._obs_cap: float | None = None   # cap the EWMA was observed under
        self._expected_cache: dict[float, float] = {}   # cap -> time/sample

        self._unsubs = [
            bus.subscribe(StepDone, self._on_step),
            bus.subscribe(PowerSampled, self._on_power),
            bus.subscribe(PolicyUpdated, self._on_policy),
        ]

        if warm_start is not None and len(warm_start.measurements) >= 3:
            for m in warm_start.measurements:
                self._bucket(m.cap).add(m.energy_j, m.delay_s, m.samples, 0.0)
            self.decision = warm_start
            self.mode = "hold"
            self._apply(warm_start.cap, "decision")
        elif self._grid:
            self._apply(self._grid[0], "probe")

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        for u in self._unsubs:
            u()

    def _legal_grid(self) -> tuple[float, ...]:
        return tuple(c for c in self._full_grid
                     if self.policy.min_cap <= c <= self.policy.max_cap)

    def _bucket(self, cap: float) -> _CapBucket:
        key = self._nearest_grid_cap(cap)
        return self._buckets.setdefault(key, _CapBucket())

    def _nearest_grid_cap(self, cap: float) -> float:
        grid = self._grid or self._full_grid
        return float(min(grid, key=lambda c: abs(c - cap)))

    def _apply(self, cap: float, reason: str) -> None:
        if abs(self.backend.current_cap() - cap) > 1e-9:
            self.n_cap_changes += 1
        self.backend.apply_cap(cap)
        self.bus.publish(CapApplied(node_id=self.node_id, cap=float(cap),
                                    reason=reason, source="online-profiler",
                                    model_id=self.model_id))

    # -- event handlers -------------------------------------------------------
    def _on_power(self, ev: PowerSampled) -> None:
        if ev.node_id == self.node_id:
            self._last_watts = ev.total_w

    def _on_policy(self, ev: PolicyUpdated) -> None:
        if ev.node_id != self.node_id:
            return
        self.policy = ev.policy
        self._grid = self._legal_grid()
        # Cost exponents changed, but the (energy, delay) physics did not:
        # refit from the accumulated buckets when we can, otherwise resweep.
        # The cost landscape's SHAPE changed with the exponent, so the old
        # coefficients are not a trustworthy seed — full multi-start here.
        self._buckets = {c: b for c, b in self._buckets.items()
                         if self.policy.min_cap <= c <= self.policy.max_cap}
        if not self._try_refit(reason="policy", fresh=True):
            self._restart_sweep()

    def _on_step(self, ev: StepDone) -> None:
        if ev.node_id != self.node_id:
            return
        if self.model_id and ev.model_id and ev.model_id != self.model_id:
            return
        self.n_steps += 1
        cap = float(self.backend.current_cap())
        energy = ev.energy_j if ev.energy_j > 0 else self._last_watts * ev.duration_s

        if energy <= 0:
            # No usable energy telemetry yet (no sampler attached, or its
            # first 0.1 Hz sample hasn't landed).  Never probe-throttle the
            # pipeline on blind data: after a few such steps park at the
            # highest legal cap and wait for telemetry.
            self._no_energy_steps += 1
            if (self.mode in ("sweep", "refresh")
                    and self._no_energy_steps >= 3 and self._grid):
                self.mode = "waiting"
                self._apply(self._grid[-1], "fallback")
            elif self.mode == "hold":
                self._advance_hold(ev)       # drift check is time-based
            return
        self._no_energy_steps = 0
        if self.mode == "waiting":           # telemetry is back: start over
            # This step ran at the parked cap — its data is valid for that
            # bucket, but it must not count toward the fresh grid[0] probe
            # window (with steps_per_probe=1 it would skip grid[0] entirely).
            self._bucket(cap).add(energy, ev.duration_s, max(ev.samples, 1),
                                  self.decay)
            self._restart_sweep()
            return

        self._steps_in_state += 1
        self._bucket(cap).add(energy, ev.duration_s, max(ev.samples, 1),
                              self.decay)

        if self.mode == "sweep":
            self._advance_sweep()
        elif self.mode == "refresh":
            self._advance_refresh()
        else:
            self._advance_hold(ev)

    # -- state machine --------------------------------------------------------
    def _advance_sweep(self) -> None:
        if self._steps_in_state < self.steps_per_probe:
            return
        self._steps_in_state = 0
        self._probe_idx += 1
        if self._probe_idx < len(self._grid):
            self._apply(self._grid[self._probe_idx], "probe")
            return
        if not self._try_refit(reason="sweep"):
            self._restart_sweep()          # degenerate data; probe again
            return
        self.mode = "hold"

    def _advance_refresh(self) -> None:
        if self._steps_in_state < self.steps_per_probe:
            return
        self._steps_in_state = 0
        refitted = self._try_refit(reason="refresh")   # applies the new cap
        self.mode = "hold"
        if not refitted and self.decision is not None:
            self._apply(self.decision.cap, "decision") # leave the probe cap

    def _advance_hold(self, ev: StepDone) -> None:
        self._check_drift(ev)
        if self.mode != "hold":            # drift restarted the sweep
            return
        # Refresh cadence is bounded in BOTH steps and wall time: a fast step
        # loop must not refit (simplex over 7 coefficients) every few ms.
        if (self._steps_in_state >= self.hold_steps and self._grid
                and self._clock() - self._last_refit_t
                >= self.min_refresh_interval_s):
            # Amortised refresh: revisit ONE grid cap, round-robin.
            self._steps_in_state = 0
            self._refresh_idx = (self._refresh_idx + 1) % len(self._grid)
            self.mode = "refresh"
            self._apply(self._grid[self._refresh_idx], "probe")

    def _check_drift(self, ev: StepDone) -> None:
        if self.decision is None:
            return
        cap = float(self.backend.current_cap())
        if self._obs_cap is None or abs(cap - self._obs_cap) > 1e-9:
            # The enforced cap changed under us (e.g. a coordinator
            # rebalance): old-cap step times must not blend into the EWMA or
            # a legitimate cap change reads as workload drift.
            self._obs_cap = cap
            self._obs_time_ewma = None
            self._obs_count = 0
        observed = ev.duration_s / max(ev.samples, 1)
        if self._obs_time_ewma is None:
            self._obs_time_ewma = observed
        else:
            self._obs_time_ewma = 0.5 * self._obs_time_ewma + 0.5 * observed
        self._obs_count += 1
        if self._obs_count < self.drift_min_steps:
            return
        expected = self._expected_cache.get(cap)
        if expected is None:
            expected = interp_measurements(self.decision.measurements, cap)[1]
            self._expected_cache[cap] = expected
        if expected <= 0:
            return
        drift = abs(self._obs_time_ewma - expected) / expected
        if drift > self.drift_threshold:
            self.bus.publish(DriftDetected(
                node_id=self.node_id, model_id=self.model_id,
                drift=float(drift), expected_s=float(expected),
                observed_s=float(self._obs_time_ewma)))
            self._buckets.clear()
            self.decision = None
            self._restart_sweep()

    def _restart_sweep(self) -> None:
        self.mode = "sweep"
        self._probe_idx = 0
        self._steps_in_state = 0
        self._obs_time_ewma = None
        self._obs_count = 0
        if self._grid:
            self._apply(self._grid[0], "probe")

    def _cost_at(self, meas: Sequence[CapMeasurement], cap: float) -> float:
        """Measured (probe-interpolated) ED^mP cost at ``cap``."""
        e, t = interp_measurements(meas, cap)
        return e * t ** self.policy.edp_exponent

    def _delay_ok(self, meas: Sequence[CapMeasurement], cap: float) -> bool:
        if self.policy.max_delay_increase is None:
            return True
        ref = max(meas, key=lambda r: r.cap)
        _, t = interp_measurements(meas, cap)
        return t / ref.time_per_sample - 1.0 <= self.policy.max_delay_increase

    def _choose_cap(self, candidate: CapDecision,
                    meas: Sequence[CapMeasurement]) -> float:
        """Robustify the fitted minimiser against two streaming failure modes:

        the MSE of the 7-coefficient fit is dominated by the deep-cap cost
        blow-up, so a fit can pass the 5% gate yet miss the shallow bowl near
        100% and park the minimiser on the boundary.  Guard 1: if the best
        *measured* probe beats the fitted cap's measured cost by more than
        ``switch_margin``, trust the probe.  Guard 2 (hysteresis): only move
        off the currently-applied decision cap when the winner improves on it
        by more than ``switch_margin`` — otherwise refits on slightly
        perturbed buckets flap the cap for no energy win.  Genuine workload
        changes bypass the hysteresis via drift detection (full resweep)."""
        chosen = candidate.cap
        legal = [r for r in meas if self._delay_ok(meas, r.cap)]
        if legal:
            best_probe = min(legal, key=lambda r: r.cost(self.policy.edp_exponent))
            if (best_probe.cost(self.policy.edp_exponent)
                    < self._cost_at(meas, chosen) * (1.0 - self.switch_margin)):
                chosen = best_probe.cap
        # Hysteresis only ever defends a cap that is still LEGAL: a policy
        # update narrowing the window must not let the old cap persist.
        if self.decision is not None:
            held = self.decision.cap
            if (self.policy.min_cap <= held <= self.policy.max_cap
                    and self._delay_ok(meas, held)
                    and self._cost_at(meas, chosen)
                    > self._cost_at(meas, held) * (1.0 - self.switch_margin)):
                chosen = held
        return float(chosen)

    def _try_refit(self, reason: str, fresh: bool = False) -> bool:
        meas = [b.measurement(c) for c, b in sorted(self._buckets.items())
                if b.samples > 0 and b.delay_s > 0 and b.energy_j > 0]
        if len(meas) < 3:
            return False
        # Incremental refits (the data moved slightly) warm-start the simplex
        # from the previous coefficients and skip the multi-start sweep — an
        # order of magnitude cheaper per refit.  ``fresh`` forces the full
        # multi-start (policy changes reshape the cost landscape), and a fit
        # that failed the 5% gate is never a seed — warm-starting from it
        # could pin every later refit in the same rejected basin.
        x0 = None if (fresh or self.decision is None
                      or not self.decision.fit.accepted) \
            else self.decision.fit.coef
        try:
            decision = decide_cap(meas, self.policy, fit_x0=x0,
                                  fit_multi_start=x0 is None)
        except ValueError:
            return False
        cap = self._choose_cap(decision, meas)
        if abs(cap - decision.cap) > 1e-12:
            decision = dataclasses.replace(decision, cap=cap)
        self.n_refits += 1
        self._last_refit_t = self._clock()
        self._expected_cache.clear()
        changed = (self.decision is None
                   or abs(decision.cap - self.decision.cap) > 1e-9)
        self.decision = decision
        self._obs_time_ewma = None
        self._obs_count = 0
        self.bus.publish(FitUpdated(node_id=self.node_id,
                                    model_id=self.model_id,
                                    fit=decision.fit, cap=decision.cap,
                                    n_probes=len(meas)))
        self._apply(decision.cap, "decision")
        if changed and self.on_decision is not None:
            self.on_decision(decision)
        return True

    # -- introspection --------------------------------------------------------
    @property
    def measurements(self) -> list[CapMeasurement]:
        return [b.measurement(c) for c, b in sorted(self._buckets.items())
                if b.samples > 0]

    def expected_time_per_sample(self, cap: float | None = None) -> float:
        if self.decision is None:
            return float("nan")
        cap = self.backend.current_cap() if cap is None else cap
        return interp_measurements(self.decision.measurements, cap)[1]
