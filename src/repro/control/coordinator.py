"""Cluster coordinator — live-telemetry-driven power shifting (Sec II-C).

The seed's ``powershift.allocate_power`` was only ever called from examples
with hand-written derates.  Here it becomes the policy engine of a closed
loop: per-node ``StepDone``/``PowerSampled`` events stream into the
coordinator, which maintains an EWMA health picture of every node,
*re-estimates* each node's thermal derate from observed vs. predicted step
time, and periodically re-runs the allocator to split the global power
budget — emitting per-node cap commands through each node's existing
``CapBackend`` and publishing ``CapApplied(reason="rebalance")`` events.

The derate estimate is what closes the loop: a node that throttles mid-run
shows up as observed_step_time > model prediction at its current cap; the
next rebalance hands it a larger share of the budget (or caps its healthy
neighbours harder), exactly the straggler-mitigation story of
``runtime.fault.Supervisor`` but driven by streamed telemetry instead of a
one-shot report.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.control.bus import EventBus
from repro.control.events import (CapApplied, NodeDerated, PowerSampled,
                                  StepDone)
from repro.core.powermodel import PowerCappedDevice, WorkloadProfile
from repro.core.powershift import ClusterNode, ShiftPlan, allocate_power
from repro.core.profiler import CapBackend, RecordingBackend


@dataclasses.dataclass
class _NodeState:
    node: ClusterNode
    backend: CapBackend
    healthy_device: PowerCappedDevice    # derate=1 reference for inference
    step_time_ewma: float | None = None
    watts_ewma: float | None = None
    n_steps: int = 0
    derate_est: float = 1.0


class ClusterCoordinator:
    """Subscribes to per-node telemetry; rebalances the global budget."""

    def __init__(
        self,
        bus: EventBus,
        *,
        global_budget_w: float,
        rebalance_every: int = 16,
        ewma: float = 0.5,
        min_derate: float = 0.2,
        on_plan: Callable[[ShiftPlan], None] | None = None,
    ) -> None:
        self.bus = bus
        self.global_budget_w = float(global_budget_w)
        self.rebalance_every = int(rebalance_every)
        self.ewma = float(ewma)
        self.min_derate = float(min_derate)
        self.on_plan = on_plan
        self._nodes: dict[str, _NodeState] = {}
        self._steps_since_rebalance = 0
        self.plans: list[ShiftPlan] = []
        self.audit: list[dict] = []      # allocated vs measured watts per plan
        self._unsubs = [
            bus.subscribe(StepDone, self._on_step),
            bus.subscribe(PowerSampled, self._on_power),
            bus.subscribe(NodeDerated, self._on_derated),
        ]

    def close(self) -> None:
        for u in self._unsubs:
            u()

    # -- membership -----------------------------------------------------------
    def register_node(self, node: ClusterNode,
                      backend: CapBackend | None = None) -> CapBackend:
        backend = backend or RecordingBackend()
        self._nodes[node.node_id] = _NodeState(
            node=node, backend=backend,
            healthy_device=PowerCappedDevice(node.device.spec),
            derate_est=node.device.derate)
        return backend

    def deregister_node(self, node_id: str) -> None:
        self._nodes.pop(node_id, None)

    @property
    def node_ids(self) -> list[str]:
        return list(self._nodes)

    # -- telemetry ingestion --------------------------------------------------
    def _on_power(self, ev: PowerSampled) -> None:
        st = self._nodes.get(ev.node_id)
        if st is None:
            return
        w = ev.total_w
        st.watts_ewma = w if st.watts_ewma is None \
            else self.ewma * st.watts_ewma + (1 - self.ewma) * w

    def _on_step(self, ev: StepDone) -> None:
        st = self._nodes.get(ev.node_id)
        if st is None:
            return
        st.n_steps += 1
        t = ev.duration_s
        st.step_time_ewma = t if st.step_time_ewma is None \
            else self.ewma * st.step_time_ewma + (1 - self.ewma) * t
        self._steps_since_rebalance += 1
        if self._steps_since_rebalance >= self.rebalance_every:
            self.rebalance()

    def _on_derated(self, ev: NodeDerated) -> None:
        """A supervisor inferred a derate out-of-band (heartbeat latencies,
        not step telemetry).  Adopt it directly — it is fresher than the
        rebalance-window estimate and the next `_update_derate` will refine
        it once step telemetry under the new caps accumulates."""
        st = self._nodes.get(ev.node_id)
        if st is None:
            return
        st.derate_est = float(min(1.0, max(self.min_derate, ev.derate)))

    def _update_derate(self, st: _NodeState) -> None:
        """Observed/predicted step time at the node's current cap -> an
        effective derate (clock multiplier) for the next allocation.  Runs
        once per rebalance window, not per step: the fixed-point power-model
        solve is too heavy for the synchronous step path."""
        if st.step_time_ewma is None or st.step_time_ewma <= 0:
            return
        cap = st.backend.current_cap()
        predicted = st.healthy_device.estimate(st.node.workload,
                                               cap).step_time_s
        if predicted <= 0:
            return
        ratio = predicted / st.step_time_ewma              # <1 => slower than model
        st.derate_est = float(min(1.0, max(self.min_derate, ratio)))

    def update_workload(self, node_id: str, workload: WorkloadProfile) -> None:
        """Telemetry-independent workload refresh (e.g. recompiled step)."""
        st = self._nodes[node_id]
        st.node = dataclasses.replace(st.node, workload=workload)

    # -- the control action ---------------------------------------------------
    def rebalance(self) -> ShiftPlan:
        """Re-run the water-filling allocator over the live health picture and
        push per-node cap commands through each node's backend."""
        if not self._nodes:
            raise RuntimeError("no nodes registered")
        self._steps_since_rebalance = 0
        live_nodes = []
        for st in self._nodes.values():
            self._update_derate(st)
            device = PowerCappedDevice(st.node.device.spec,
                                       derate=st.derate_est)
            live_nodes.append(dataclasses.replace(st.node, device=device))
        plan = allocate_power(live_nodes, self.global_budget_w)
        for alloc in plan.allocations:
            st = self._nodes[alloc.node_id]
            if abs(st.backend.current_cap() - alloc.cap) > 1e-6:
                st.backend.apply_cap(alloc.cap)
                self.bus.publish(CapApplied(node_id=alloc.node_id,
                                            cap=alloc.cap,
                                            reason="rebalance",
                                            source="cluster-coordinator"))
        self.plans.append(plan)
        # Budget audit: allocation is model-based; the measured draw (EWMA of
        # PowerSampled telemetry) is the ground truth the budget is actually
        # enforced against.  The EWMA was accumulated under the caps of the
        # window that just ENDED, so `window_over_budget` audits the previous
        # plan's enforcement, not the plan being installed now.  A large gap
        # between allocated and measured flags a mis-calibrated power model.
        measured = self.measured_total_w()
        self.audit.append({"allocated_w": plan.total_power_w,
                           "window_measured_w": measured,
                           "budget_w": self.global_budget_w,
                           "window_over_budget": (measured is not None
                                                  and measured > self.global_budget_w)})
        # The caps just changed: step-time/watts EWMAs accumulated under the
        # OLD caps would be compared against new-cap predictions at the next
        # rebalance, pushing derate estimates into oscillation.  Start the
        # next health window clean (derate_est itself persists).
        for st in self._nodes.values():
            st.step_time_ewma = None
            st.watts_ewma = None
        if self.on_plan is not None:
            self.on_plan(plan)
        return plan

    def measured_total_w(self) -> float | None:
        """Sum of per-node measured power EWMAs; None until every registered
        node has reported at least one PowerSampled."""
        watts = [st.watts_ewma for st in self._nodes.values()]
        if any(w is None for w in watts):
            return None
        return float(sum(watts))

    def current_caps(self) -> dict[str, float]:
        return {nid: st.backend.current_cap()
                for nid, st in self._nodes.items()}

    def derates(self) -> dict[str, float]:
        return {nid: st.derate_est for nid, st in self._nodes.items()}
