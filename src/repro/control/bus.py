"""In-process event bus — the spine of the FROST control plane.

Synchronous, typed publish/subscribe.  Handlers are registered against an
event *class* and receive every published event that ``isinstance``-matches
it (so a handler on ``Event`` sees everything).  Publishing is synchronous
and in-order: by the time ``publish`` returns, every matching handler has
run.  That makes the control loop deterministic and testable — and keeps
the overhead per step down to a dict lookup plus direct calls (benchmarked
in ``benchmarks/ctrl_overhead.py`` against the paper's 0.1 Hz sampler).

Thread-safety: ``PowerSampler`` publishes from its daemon thread while the
step loop publishes ``StepDone`` from the main thread, so subscription
tables are guarded by an RLock (re-entrant: handlers may publish follow-up
events from within a dispatch).

Handler errors are isolated: a failing subscriber is recorded in
``bus.errors`` and never breaks the pipeline step that published the event
(O-RAN reliability mandate — telemetry must not take down serving).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Deque, Iterable, Type

from repro.control.events import Event

Handler = Callable[[Event], None]


class EventBus:
    def __init__(self, *, history: int = 256,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self._lock = threading.RLock()
        self._subs: dict[Type[Event], list[Handler]] = {}
        self._clock = clock
        self.history: Deque[tuple[float, Event]] = collections.deque(maxlen=history)
        # Bounded like history: a persistently-failing subscriber on a
        # multi-day run must not grow memory linearly with steps.
        self.errors: Deque[tuple[Event, Handler, Exception]] = \
            collections.deque(maxlen=max(history, 64))
        self.n_published = 0
        self.n_delivered = 0
        self.n_errors = 0

    # -- subscription ---------------------------------------------------------
    def subscribe(self, event_type: Type[Event], handler: Handler) -> Callable[[], None]:
        """Register ``handler`` for events matching ``event_type``; returns an
        unsubscribe callable."""
        with self._lock:
            self._subs.setdefault(event_type, []).append(handler)

        def unsubscribe() -> None:
            with self._lock:
                handlers = self._subs.get(event_type, [])
                if handler in handlers:
                    handlers.remove(handler)

        return unsubscribe

    def subscribers(self, event_type: Type[Event]) -> int:
        with self._lock:
            return len(self._subs.get(event_type, []))

    # -- publication ----------------------------------------------------------
    def publish(self, event: Event) -> int:
        """Dispatch ``event`` to every matching handler; returns the number of
        handlers that ran (exceptions included — see ``errors``)."""
        with self._lock:
            matched = [h for etype, handlers in self._subs.items()
                       if isinstance(event, etype) for h in handlers]
            self.history.append((self._clock(), event))
            self.n_published += 1
        delivered = 0
        for handler in matched:
            try:
                handler(event)
            except Exception as exc:            # noqa: BLE001 — isolation
                with self._lock:                # publishers race on errors
                    self.errors.append((event, handler, exc))
                    self.n_errors += 1
            delivered += 1
        with self._lock:
            self.n_delivered += delivered
        return delivered

    def tap(self, event_type: Type[Event]) -> list[Event]:
        """Lossless capture: returns a list that every future matching event
        is appended to (``history`` is a bounded ring — use this when an
        exact log matters, e.g. end-of-run cap-command accounting)."""
        captured: list[Event] = []
        self.subscribe(event_type, captured.append)
        return captured

    # -- introspection --------------------------------------------------------
    def events_of(self, event_type: Type[Event]) -> list[Event]:
        """Matching events still in the history ring (newest last)."""
        with self._lock:
            return [e for _, e in self.history if isinstance(e, event_type)]

    def drain_errors(self) -> list[tuple[Event, Handler, Exception]]:
        out = list(self.errors)
        self.errors.clear()
        return out


def pipe(bus_from: EventBus, bus_to: EventBus,
         event_types: Iterable[Type[Event]] = (Event,)) -> list[Callable[[], None]]:
    """Forward selected event types between buses (e.g. per-node buses into a
    cluster coordinator bus).  Returns the unsubscribe callables."""
    return [bus_from.subscribe(t, bus_to.publish) for t in event_types]
