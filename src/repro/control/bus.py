"""In-process event bus — the spine of the FROST control plane.

Synchronous, typed publish/subscribe.  Handlers are registered against an
event *class* and receive every published event that ``isinstance``-matches
it (so a handler on ``Event`` sees everything).  Publishing is synchronous
and in-order: by the time ``publish`` returns, every matching handler has
run.  That makes the control loop deterministic and testable — and keeps
the overhead per step down to a dict lookup plus direct calls (benchmarked
in ``benchmarks/ctrl_overhead.py`` against the paper's 0.1 Hz sampler).

Thread-safety: ``PowerSampler`` publishes from its daemon thread while the
step loop publishes ``StepDone`` from the main thread, so subscription
tables are guarded by an RLock (re-entrant: handlers may publish follow-up
events from within a dispatch).

Handler errors are isolated: a failing subscriber is retried up to
``max_retries`` times with exponential backoff, then recorded in
``bus.errors`` AND ``bus.dead_letters`` — never breaking the pipeline step
that published the event (O-RAN reliability mandate — telemetry must not
take down serving).  Dead letters keep the event so a recovered consumer
can be replayed via ``redeliver_dead_letters`` — dropped/late telemetry
degrades the control loop's freshness, never its liveness.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Iterable, Type

from repro.control.events import Event

Handler = Callable[[Event], None]


@dataclasses.dataclass
class DeadLetter:
    """One undeliverable event: every retry of ``handler`` failed."""
    event: Event
    handler: Handler
    attempts: int
    error: Exception
    t: float


class EventBus:
    def __init__(self, *, history: int = 256,
                 clock: Callable[[], float] = time.monotonic,
                 max_retries: int = 2, backoff_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        self._lock = threading.RLock()
        self._subs: dict[Type[Event], list[Handler]] = {}
        self._clock = clock
        # Delivery is at-most-(1 + max_retries) attempts per handler; the
        # default backoff of 0.0 keeps the synchronous fast path sleep-free
        # (a transiently-failing handler usually recovers on the immediate
        # retry); set backoff_s > 0 for true exponential spacing.
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self._sleep = sleep
        self.history: Deque[tuple[float, Event]] = collections.deque(maxlen=history)
        # Bounded like history: a persistently-failing subscriber on a
        # multi-day run must not grow memory linearly with steps.
        self.errors: Deque[tuple[Event, Handler, Exception]] = \
            collections.deque(maxlen=max(history, 64))
        self.dead_letters: Deque[DeadLetter] = \
            collections.deque(maxlen=max(history, 64))
        self.n_published = 0
        self.n_delivered = 0
        self.n_errors = 0
        self.n_retries = 0
        self.n_dead_lettered = 0

    # -- subscription ---------------------------------------------------------
    def subscribe(self, event_type: Type[Event], handler: Handler) -> Callable[[], None]:
        """Register ``handler`` for events matching ``event_type``; returns an
        unsubscribe callable."""
        with self._lock:
            self._subs.setdefault(event_type, []).append(handler)

        def unsubscribe() -> None:
            with self._lock:
                handlers = self._subs.get(event_type, [])
                if handler in handlers:
                    handlers.remove(handler)

        return unsubscribe

    def subscribers(self, event_type: Type[Event]) -> int:
        with self._lock:
            return len(self._subs.get(event_type, []))

    # -- publication ----------------------------------------------------------
    def publish(self, event: Event) -> int:
        """Dispatch ``event`` to every matching handler; returns the number of
        handlers that ran (exceptions included — see ``errors``)."""
        with self._lock:
            matched = [h for etype, handlers in self._subs.items()
                       if isinstance(event, etype) for h in handlers]
            self.history.append((self._clock(), event))
            self.n_published += 1
        delivered = 0
        for handler in matched:
            self._deliver(event, handler)
            delivered += 1
        with self._lock:
            self.n_delivered += delivered
        return delivered

    def _deliver(self, event: Event, handler: Handler) -> bool:
        """One handler, up to ``1 + max_retries`` attempts with exponential
        backoff.  On exhaustion the event is dead-lettered (one ``errors``
        record per *final* failure, not per attempt)."""
        attempts = 1 + max(0, self.max_retries)
        delay = self.backoff_s
        for attempt in range(1, attempts + 1):
            try:
                handler(event)
                return True
            except Exception as exc:            # noqa: BLE001 — isolation
                last = exc
                if attempt < attempts:
                    with self._lock:
                        self.n_retries += 1
                    if delay > 0.0:
                        self._sleep(delay)
                        delay *= 2.0
        with self._lock:                        # publishers race on errors
            self.errors.append((event, handler, last))
            self.n_errors += 1
            self.dead_letters.append(DeadLetter(
                event=event, handler=handler, attempts=attempts,
                error=last, t=self._clock()))
            self.n_dead_lettered += 1
        return False

    def tap(self, event_type: Type[Event]) -> list[Event]:
        """Lossless capture: returns a list that every future matching event
        is appended to (``history`` is a bounded ring — use this when an
        exact log matters, e.g. end-of-run cap-command accounting)."""
        captured: list[Event] = []
        self.subscribe(event_type, captured.append)
        return captured

    # -- introspection --------------------------------------------------------
    def events_of(self, event_type: Type[Event]) -> list[Event]:
        """Matching events still in the history ring (newest last)."""
        with self._lock:
            return [e for _, e in self.history if isinstance(e, event_type)]

    def drain_errors(self) -> list[tuple[Event, Handler, Exception]]:
        out = list(self.errors)
        self.errors.clear()
        return out

    def redeliver_dead_letters(self) -> int:
        """Replay dead letters to their original handlers (e.g. after a
        consumer recovered).  Returns the number redelivered successfully;
        still-failing letters are re-dead-lettered by ``_deliver``."""
        with self._lock:
            letters = list(self.dead_letters)
            self.dead_letters.clear()
        return sum(self._deliver(dl.event, dl.handler) for dl in letters)


def pipe(bus_from: EventBus, bus_to: EventBus,
         event_types: Iterable[Type[Event]] = (Event,)) -> list[Callable[[], None]]:
    """Forward selected event types between buses (e.g. per-node buses into a
    cluster coordinator bus).  Returns the unsubscribe callables."""
    return [bus_from.subscribe(t, bus_to.publish) for t in event_types]
