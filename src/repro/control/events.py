"""Typed control-plane events — the vocabulary of the FROST loop.

The paper's Fig 1 runs FROST *in parallel to* the ML pipeline: telemetry
streams out of the running job, decisions stream back in as cap commands.
These dataclasses are the wire format of that loop.  They are deliberately
plain (frozen dataclasses; no runtime imports from the rest of the repo,
so ``repro.core`` modules can publish them without import cycles) and can
later cross a real message bus (O-RAN A1/E2 realisation) without changing
any producer or consumer.

Producers / consumers at a glance::

    StepDone       launch loops, Supervisor        -> OnlineCapProfiler,
                                                      FrostService, Coordinator
    PowerSampled   telemetry.PowerSampler          -> OnlineCapProfiler, Coordinator
    CapApplied     profilers, coordinator          -> observers / ledgers
    DriftDetected  OnlineCapProfiler, FrostService -> re-profiling triggers
    PolicyUpdated  SMO / FrostService.on_policy    -> profilers (reset + retune)
    FitUpdated     OnlineCapProfiler               -> observers / warm-start caches
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Mapping

if TYPE_CHECKING:                                  # no runtime dependency
    from repro.core.fitting import FitResult
    from repro.core.policy import QoSPolicy


@dataclasses.dataclass(frozen=True)
class Event:
    """Base class — every event names the node it concerns."""
    node_id: str


@dataclasses.dataclass(frozen=True)
class StepDone(Event):
    """One pipeline step (train step / decode token batch) finished."""
    step: int
    duration_s: float
    samples: int = 1
    energy_j: float = 0.0        # 0 => unknown; consumers may estimate from
                                 # the latest PowerSampled watts
    model_id: str = ""


@dataclasses.dataclass(frozen=True)
class PowerSampled(Event):
    """One telemetry sample (paper Eq 3 components), watts."""
    t: float
    cpu_w: float = 0.0
    gpu_w: float = 0.0
    dram_w: float = 0.0

    @property
    def total_w(self) -> float:
        return self.cpu_w + self.gpu_w + self.dram_w


@dataclasses.dataclass(frozen=True)
class CapApplied(Event):
    """A power cap was enforced through a CapBackend."""
    cap: float
    reason: str = "decision"     # "probe" | "decision" | "rebalance" | "policy"
    source: str = ""             # who applied it (profiler / coordinator / ...)
    model_id: str = ""


@dataclasses.dataclass(frozen=True)
class DriftDetected(Event):
    """Observed throughput departed from the profiled expectation."""
    model_id: str
    drift: float                 # |observed - expected| / expected
    expected_s: float
    observed_s: float


@dataclasses.dataclass(frozen=True)
class PolicyUpdated(Event):
    """A new A1 QoS policy is in force for the node."""
    policy: "QoSPolicy"

    @property
    def policy_id(self) -> str:
        return self.policy.policy_id


@dataclasses.dataclass(frozen=True)
class FitUpdated(Event):
    """The online profiler refreshed its F(x) fit (paper Eqs 6-7)."""
    model_id: str
    fit: "FitResult"
    cap: float                   # minimiser under the active policy
    n_probes: int


@dataclasses.dataclass(frozen=True)
class NodeDerated(Event):
    """A supervisor inferred a thermal/silicon derate from heartbeat
    latencies (1.0 = healthy).  The cluster coordinator folds this into
    its next power rebalance — the serving half of the FROST
    straggler-mitigation loop (see docs/fault_tolerance.md)."""
    derate: float
    source: str = ""             # who inferred it (supervisor / coordinator)


@dataclasses.dataclass(frozen=True)
class EmergencyPower(Event):
    """A power emergency (site cap slash / thermal trip) started or
    cleared.  Serving reacts by *degrading* — pause admission, shrink the
    decode chunk, drop speculative K — instead of violating the cap."""
    cap: float                   # cap fraction in force for the window
    active: bool                 # True = window opened, False = cleared
    reason: str = "emergency"


def as_dict(event: Event) -> Mapping[str, Any]:
    """Loggable view (FitResult/QoSPolicy collapsed to identifiers)."""
    out: dict[str, Any] = dataclasses.asdict(event)
    if isinstance(event, FitUpdated):
        out["fit"] = {"rel_rmse": event.fit.rel_rmse,
                      "accepted": event.fit.accepted}
    if isinstance(event, PolicyUpdated):
        out["policy"] = event.policy.policy_id
    out["type"] = type(event).__name__
    return out
