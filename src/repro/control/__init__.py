"""Event-driven FROST control plane.

The paper's Fig 1 loop — telemetry out of the running pipeline, cap
decisions back in — realised as an in-process typed event bus plus two
controllers:

  * ``EventBus`` + event types (``bus``/``events``): the spine; producers
    (step loops, ``PowerSampler``) and consumers (profilers, coordinator,
    ``FrostService``) meet here instead of calling each other directly.
  * ``OnlineCapProfiler`` (``online``): amortises the paper's 8-point probe
    across live traffic and retunes the cap as events stream in.
  * ``ClusterCoordinator`` (``coordinator``): re-runs the power-shift
    allocator over live per-node telemetry and emits cap commands.

``online``/``coordinator`` are exported lazily (PEP 562) because they pull
in ``repro.core``, which itself publishes events from this package.
"""
from repro.control.bus import DeadLetter, EventBus, pipe
from repro.control.events import (CapApplied, DriftDetected, EmergencyPower,
                                  Event, FitUpdated, NodeDerated,
                                  PolicyUpdated, PowerSampled, StepDone,
                                  as_dict)

__all__ = [
    "EventBus", "DeadLetter", "pipe",
    "Event", "StepDone", "PowerSampled", "CapApplied", "DriftDetected",
    "PolicyUpdated", "FitUpdated", "NodeDerated", "EmergencyPower", "as_dict",
    "OnlineCapProfiler", "ClusterCoordinator",
]


def __getattr__(name: str):
    if name == "OnlineCapProfiler":
        from repro.control.online import OnlineCapProfiler
        return OnlineCapProfiler
    if name == "ClusterCoordinator":
        from repro.control.coordinator import ClusterCoordinator
        return ClusterCoordinator
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
