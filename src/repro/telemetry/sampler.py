"""Background power sampler — FROST runs *in parallel to* the ML pipeline
(paper Sec I) at 0.1 Hz default (Fig 3: lower rate ⇒ lower overhead than
CodeCarbon/Eco2AI's 1 Hz, at equal energy-trend fidelity).

When handed a control-plane bus, every sample is also published as a
``PowerSampled`` event (from the sampler's daemon thread — the bus is
thread-safe), feeding the online profiler and the cluster coordinator in
addition to the private ``EnergyLedger``.
"""
from __future__ import annotations

import threading
import time
from typing import TYPE_CHECKING, Callable

from repro.control.events import PowerSampled
from repro.core.energy import EnergyLedger, PowerSample
from repro.telemetry.meters import Meter, StackedMeter

if TYPE_CHECKING:
    from repro.control.bus import EventBus


class PowerSampler:
    """Samples meters on a daemon thread into an EnergyLedger (and onto the
    control-plane bus, when attached)."""

    def __init__(self, meters: dict[str, Meter], *, rate_hz: float = 0.1,
                 clock: Callable[[], float] = time.monotonic,
                 bus: "EventBus | None" = None, node_id: str = "node-0"):
        self.meters = meters
        self.period = 1.0 / rate_hz
        self.clock = clock
        self.ledger = EnergyLedger()
        self.bus = bus
        self.node_id = node_id
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.n_samples = 0

    def sample_once(self) -> PowerSample:
        s = PowerSample(
            t=self.clock(),
            cpu_w=self.meters.get("cpu", _ZERO).read_watts(),
            gpu_w=self.meters.get("gpu", _ZERO).read_watts(),
            dram_w=self.meters.get("dram", _ZERO).read_watts(),
        )
        self.ledger.record(s)
        self.n_samples += 1
        if self.bus is not None:
            self.bus.publish(PowerSampled(node_id=self.node_id, t=s.t,
                                          cpu_w=s.cpu_w, gpu_w=s.gpu_w,
                                          dram_w=s.dram_w))
        return s

    def __enter__(self):
        self._stop.clear()
        self.sample_once()                       # t=0 anchor

        def loop():
            while not self._stop.wait(self.period):
                self.sample_once()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        if self._thread:
            self._thread.join()
        self.sample_once()                       # closing anchor
        return False

    def capture_idle(self, duration_s: float, rate_hz: float = 2.0):
        """The paper's T_m idle window: record the idle trace once per host."""
        t_end = self.clock() + duration_s
        while self.clock() < t_end:
            self.ledger.record_idle(PowerSample(
                t=self.clock(),
                cpu_w=self.meters.get("cpu", _ZERO).read_watts(),
                gpu_w=self.meters.get("gpu", _ZERO).read_watts(),
                dram_w=self.meters.get("dram", _ZERO).read_watts(),
            ))
            time.sleep(1.0 / rate_hz)


class _Zero:
    name = "zero"

    def read_watts(self) -> float:
        return 0.0


_ZERO = _Zero()
