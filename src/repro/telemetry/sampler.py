"""Background power sampler — FROST runs *in parallel to* the ML pipeline
(paper Sec I) at 0.1 Hz default (Fig 3: lower rate ⇒ lower overhead than
CodeCarbon/Eco2AI's 1 Hz, at equal energy-trend fidelity).
"""
from __future__ import annotations

import threading
import time
from typing import Callable

from repro.core.energy import EnergyLedger, PowerSample
from repro.telemetry.meters import Meter, StackedMeter


class PowerSampler:
    """Samples meters on a daemon thread into an EnergyLedger."""

    def __init__(self, meters: dict[str, Meter], *, rate_hz: float = 0.1,
                 clock: Callable[[], float] = time.monotonic):
        self.meters = meters
        self.period = 1.0 / rate_hz
        self.clock = clock
        self.ledger = EnergyLedger()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.n_samples = 0

    def sample_once(self) -> PowerSample:
        s = PowerSample(
            t=self.clock(),
            cpu_w=self.meters.get("cpu", _ZERO).read_watts(),
            gpu_w=self.meters.get("gpu", _ZERO).read_watts(),
            dram_w=self.meters.get("dram", _ZERO).read_watts(),
        )
        self.ledger.record(s)
        self.n_samples += 1
        return s

    def __enter__(self):
        self._stop.clear()
        self.sample_once()                       # t=0 anchor

        def loop():
            while not self._stop.wait(self.period):
                self.sample_once()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        if self._thread:
            self._thread.join()
        self.sample_once()                       # closing anchor
        return False

    def capture_idle(self, duration_s: float, rate_hz: float = 2.0):
        """The paper's T_m idle window: record the idle trace once per host."""
        t_end = self.clock() + duration_s
        while self.clock() < t_end:
            self.ledger.record_idle(PowerSample(
                t=self.clock(),
                cpu_w=self.meters.get("cpu", _ZERO).read_watts(),
                gpu_w=self.meters.get("gpu", _ZERO).read_watts(),
                dram_w=self.meters.get("dram", _ZERO).read_watts(),
            ))
            time.sleep(1.0 / rate_hz)


class _Zero:
    name = "zero"

    def read_watts(self) -> float:
        return 0.0


_ZERO = _Zero()
