"""Power meters — the FROST measurement backends (paper Sec III-A).

The paper reads Intel RAPL MSRs (CPU), Nvidia NVML (GPU) and estimates DRAM
analytically.  This container exposes none of those, so the same Meter
interface is served by:

  * RaplMeter          — real /sys/class/powercap RAPL counters when present,
  * CpuProcessMeter    — process CPU-time derivative x per-core active watts
                         (works everywhere; used by the Fig 3 overhead bench),
  * DramMeter          — the paper's rule: P = N_DIMM * 3/8 * S_DIMM (GB),
  * AnalyticDeviceMeter— the calibrated PowerCappedDevice model (the stand-in
                         for NVML on the simulated accelerators).

All meters return instantaneous watts; the sampler integrates.
"""
from __future__ import annotations

import os
import pathlib
import time
from typing import Protocol

from repro.core.energy import dram_power_estimate
from repro.core.powermodel import PowerCappedDevice, WorkloadProfile


class Meter(Protocol):
    name: str

    def read_watts(self) -> float: ...


class CpuProcessMeter:
    """Derivative of this process's CPU time, scaled by watts/active-core.

    ~10 W/core active is a documented assumption for modern server cores at
    mid utilisation; it only scales relative numbers (Fig 3 compares
    *overheads*, which are time-dominated).
    """
    name = "cpu-process"

    def __init__(self, watts_per_core: float = 10.0, idle_w: float = 2.0):
        self.watts_per_core = watts_per_core
        self.idle_w = idle_w
        self._last = (time.monotonic(), self._cpu_seconds())

    @staticmethod
    def _cpu_seconds() -> float:
        t = os.times()
        return t.user + t.system

    def read_watts(self) -> float:
        now = time.monotonic()
        cpu = self._cpu_seconds()
        t0, c0 = self._last
        self._last = (now, cpu)
        dt = max(now - t0, 1e-6)
        util_cores = max(0.0, (cpu - c0) / dt)
        return self.idle_w + util_cores * self.watts_per_core


class RaplMeter:
    """Intel RAPL via powercap sysfs (graceful if absent)."""
    name = "cpu-rapl"
    BASE = pathlib.Path("/sys/class/powercap")

    def __init__(self):
        self._zones = sorted(self.BASE.glob("intel-rapl:*/energy_uj")) \
            if self.BASE.exists() else []
        self._last: tuple[float, float] | None = None

    @property
    def available(self) -> bool:
        return bool(self._zones)

    def _energy_j(self) -> float:
        total = 0.0
        for z in self._zones:
            try:
                total += int(z.read_text()) * 1e-6
            except OSError:
                pass
        return total

    def read_watts(self) -> float:
        if not self._zones:
            return 0.0
        now, e = time.monotonic(), self._energy_j()
        if self._last is None:
            self._last = (now, e)
            return 0.0
        t0, e0 = self._last
        self._last = (now, e)
        return max(0.0, (e - e0) / max(now - t0, 1e-6))


class DramMeter:
    """Paper Sec III-A: P_DRAM = N_DIMM x 3/8 x S_DIMM — load-independent."""
    name = "dram"

    def __init__(self, n_dimm: int = 4, dimm_size_gb: float = 16.0):
        self._watts = dram_power_estimate(n_dimm, dimm_size_gb)

    def read_watts(self) -> float:
        return self._watts


class AnalyticDeviceMeter:
    """NVML stand-in: the calibrated device model under the current cap and
    workload.  ``set_workload``/``set_cap`` are driven by the profiler."""
    name = "accelerator"

    def __init__(self, device: PowerCappedDevice,
                 workload: WorkloadProfile | None = None, cap: float = 1.0):
        self.device = device
        self.workload = workload
        self.cap = cap
        self.busy = False

    def set_cap(self, cap: float):
        self.cap = float(cap)

    def set_workload(self, wl: WorkloadProfile | None, busy: bool = True):
        self.workload = wl
        self.busy = busy

    def read_watts(self) -> float:
        if not self.busy or self.workload is None:
            return self.device.spec.static_w
        return self.device.estimate(self.workload, self.cap).power_w


class StackedMeter:
    """Eq (3): P(t) = P_CPU + P_GPU + P_DRAM."""
    name = "total"

    def __init__(self, *meters: Meter):
        self.meters = meters

    def read_watts(self) -> float:
        return sum(m.read_watts() for m in self.meters)

    def read_components(self) -> dict[str, float]:
        return {m.name: m.read_watts() for m in self.meters}
