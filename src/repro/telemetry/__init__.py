"""Power telemetry: meters + background sampler (paper Sec III-A)."""
from repro.telemetry.meters import (AnalyticDeviceMeter, CpuProcessMeter,
                                    DramMeter, Meter, RaplMeter, StackedMeter)
from repro.telemetry.sampler import PowerSampler

__all__ = ["Meter", "CpuProcessMeter", "RaplMeter", "DramMeter",
           "AnalyticDeviceMeter", "StackedMeter", "PowerSampler"]
