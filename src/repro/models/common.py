"""Shared model building blocks: norms, RoPE, activations, initialisers.

Pure-functional JAX: params are pytrees of jnp arrays, every module is a pair
of (init_fn, apply_fn)-style free functions.  Keeping this dependency-free
(no flax/haiku) makes the sharding rules in repro.runtime.sharding a simple
path-pattern match over the param tree.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


# --------------------------------------------------------------------------
# jax version compatibility
# --------------------------------------------------------------------------
def shard_map(fn, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: the new top-level API takes
    ``check_vma``; 0.4.x exposes ``jax.experimental.shard_map`` with the
    equivalent ``check_rep`` knob."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


# --------------------------------------------------------------------------
# dtype helpers
# --------------------------------------------------------------------------
def dt(name: str) -> jnp.dtype:
    return jnp.dtype(name)


# --------------------------------------------------------------------------
# initialisers (numpy RNG for cheap, reproducible host-side init)
# --------------------------------------------------------------------------
def normal_init(key: jax.Array, shape: tuple[int, ...], std: float,
                dtype: str = "float32") -> jax.Array:
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def fan_in_init(key: jax.Array, shape: tuple[int, ...],
                dtype: str = "float32") -> jax.Array:
    """Truncated-normal-ish scaled by 1/sqrt(fan_in) (first dim = fan_in)."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    return normal_init(key, shape, std=1.0 / np.sqrt(max(fan_in, 1)), dtype=dtype)


def zeros(shape: tuple[int, ...], dtype: str = "float32") -> jax.Array:
    return jnp.zeros(shape, dtype=dtype)


def ones(shape: tuple[int, ...], dtype: str = "float32") -> jax.Array:
    return jnp.ones(shape, dtype=dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            gemma_style: bool = False) -> jax.Array:
    """RMSNorm in fp32, cast back to x.dtype.

    ``gemma_style=True`` uses the (1 + scale) parameterisation gemma2 ships.
    """
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if gemma_style:
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def gated_rmsnorm(x: jax.Array, gate: jax.Array, scale: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
    """Mamba2's norm: RMSNorm(x * silu(gate)) — fused gate-then-norm."""
    x32 = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------
def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name!r}")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) — llama convention.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    """
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs          # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                                # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope_partial(x: jax.Array, positions: jax.Array, theta: float,
                       fraction: float = 1.0) -> jax.Array:
    """stablelm-style partial rotary: rotate only the first ``fraction`` of
    head dims, pass the rest through."""
    if fraction >= 1.0:
        return apply_rope(x, positions, theta)
    rd = int(x.shape[-1] * fraction)
    rd -= rd % 2
    rot = apply_rope(x[..., :rd], positions, theta)
    return jnp.concatenate([rot, x[..., rd:]], axis=-1)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token-level CE in fp32.  labels: int ids; mask: 1 = count."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def shift_labels(tokens: jax.Array, pad_id: int = 0):
    """Next-token prediction: inputs tokens[:, :-1] predict tokens[:, 1:]."""
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    mask = (labels != pad_id).astype(jnp.float32)
    return inputs, labels, mask


# --------------------------------------------------------------------------
# tree utilities
# --------------------------------------------------------------------------
def tree_size_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))
