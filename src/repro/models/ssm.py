"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Layer = in_proj -> short causal conv (x, B, C channels) -> SSD -> gated
RMSNorm -> out_proj.  Train/prefill run the chunked SSD kernel; decode is the
O(1)-state recurrence (``ops.ssd_decode_step``) — this is why the ssm archs
are the ones that run the long_500k shape.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common
from repro.models.attention import ParamLeaf, pl_
from repro.models.config import ModelConfig


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba(key, cfg: ModelConfig) -> dict[str, Any]:
    d, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.resolved_ssm_heads, cfg.ssm_state, cfg.ssm_groups
    W = cfg.conv_width
    keys = common.split_keys(key, 8)
    dt = cfg.param_dtype
    cd = conv_dim(cfg)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(keys[6], (H,), jnp.float32)
    dt_target = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_init = jnp.log(jnp.expm1(dt_target))   # inverse softplus
    return {
        "pre_norm": ParamLeaf(common.ones((d,), dt), (None,)),
        "wz": pl_(keys[0], (d, di), ("embed", "ssm_inner"), dtype=dt),
        "wx": pl_(keys[1], (d, di), ("embed", "ssm_inner"), dtype=dt),
        "wB": pl_(keys[2], (d, G * N), ("embed", None), dtype=dt),
        "wC": pl_(keys[3], (d, G * N), ("embed", None), dtype=dt),
        "wdt": pl_(keys[4], (d, H), ("embed", "ssm_heads"), dtype=dt),
        "conv_w": ParamLeaf(common.normal_init(keys[5], (W, cd), 0.1, dt),
                            (None, "conv_channels")),
        "conv_b": ParamLeaf(common.zeros((cd,), dt), ("conv_channels",)),
        "A_log": ParamLeaf(jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
                           ("ssm_heads",)),
        "D": ParamLeaf(common.ones((H,), dt), ("ssm_heads",)),
        "dt_bias": ParamLeaf(jnp.asarray(dt_init, dt), ("ssm_heads",)),
        "norm_scale": ParamLeaf(common.ones((di,), dt), ("ssm_inner",)),
        "wout": pl_(keys[7], (di, d), ("ssm_inner", "embed"), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv via W shifted adds (W is 4 — cheaper than a
    conv HLO and fuses).  x: (B, S, C); w: (W, C).  Returns (y, tail) where
    tail = last W-1 inputs (the decode conv state)."""
    W = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + x_pad[:, i:i + S] * w[i]
    tail = x_pad[:, -(W - 1):] if W > 1 else None
    return y + b, tail


def mamba_forward(params, x, cfg: ModelConfig, *, policy=ops.DEFAULT_POLICY,
                  constrain=None, initial=None, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: (B, S, d).

    ``initial``/``return_state``: optional (conv_tail, ssm_state) carry for
    chunked prefill / cache seeding.
    """
    adt = x.dtype
    B, S, d = x.shape
    di, H = cfg.d_inner, cfg.resolved_ssm_heads
    P_, N, G = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups

    z = x @ params["wz"].astype(adt)
    xc = x @ params["wx"].astype(adt)
    Bc = x @ params["wB"].astype(adt)
    Cc = x @ params["wC"].astype(adt)
    dt_raw = x @ params["wdt"].astype(adt)
    if constrain is not None:
        z = constrain(z, ("batch", None, "ssm_act"))
        xc = constrain(xc, ("batch", None, "ssm_act"))

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_state_in = None if initial is None else initial[0]
    conv_out, conv_tail = _causal_conv(conv_in, params["conv_w"].astype(adt),
                                       params["conv_b"].astype(adt),
                                       conv_state_in)
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :di].reshape(B, S, H, P_)
    Bc = conv_out[..., di:di + G * N].reshape(B, S, G, N)
    Cc = conv_out[..., di + G * N:].reshape(B, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    ssm_state_in = None if initial is None else initial[1]
    res = ops.ssd(xc, dt, A, Bc, Cc, params["D"], policy=policy,
                  initial_state=ssm_state_in, return_state=return_state)
    if return_state:
        y, ssm_state = res
    else:
        y, ssm_state = res, None

    y = y.reshape(B, S, di)
    y = common.gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps)
    out = y @ params["wout"].astype(adt)
    if constrain is not None:
        out = constrain(out, ("batch", None, "embed_act"))
    if return_state:
        return out, (conv_tail, ssm_state)
    return out


def mamba_decode(params, x, cache, cfg: ModelConfig, *, constrain=None):
    """One-token decode.  x: (B, 1, d); cache = (conv_tail (B,W-1,Cc),
    ssm_state (B,H,P,N)).  O(1) in context length."""
    adt = x.dtype
    B = x.shape[0]
    di, H = cfg.d_inner, cfg.resolved_ssm_heads
    P_, N, G = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    conv_state, ssm_state = cache
    xt = x[:, 0]

    z = xt @ params["wz"].astype(adt)
    xc = xt @ params["wx"].astype(adt)
    Bc = xt @ params["wB"].astype(adt)
    Cc = xt @ params["wC"].astype(adt)
    dt_raw = xt @ params["wdt"].astype(adt)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)      # (B, Cc)
    w = params["conv_w"].astype(adt)                      # (W, Cc)
    hist = jnp.concatenate([conv_state.astype(adt), conv_in[:, None]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(adt)
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = hist[:, 1:]

    xc = conv_out[:, :di].reshape(B, H, P_)
    Bc = conv_out[:, di:di + G * N].reshape(B, G, N)
    Cc = conv_out[:, di + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    ssm_state, y = ops.ssd_decode_step(ssm_state, xc, dt, A, Bc, Cc, params["D"])
    y = y.reshape(B, di)
    y = common.gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps)
    out = (y @ params["wout"].astype(adt))[:, None]
    return out, (new_conv_state, ssm_state)
