"""The paper's CIFAR-10 CNN zoo — all 16 models from FROST Sec IV.

Definitions follow the community implementations the paper used
(kuangliu/pytorch-cifar), re-expressed as pure-functional JAX.  These models
are what the paper-figure benchmarks (fig2/3/4/5/6) train and profile; the
LM architectures are the beyond-paper deployment target.

Simplifications, recorded per the hardware-adaptation contract:
  * BatchNorm uses batch statistics in both train and eval (no running
    stats) — identical FLOP/byte profile, which is FROST's measurement axis.
  * The exotic cells (PNASNet, DPN, SimpleDLA, RegNet) follow the
    pytorch-cifar reduced CIFAR variants, not the ImageNet originals.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


# --------------------------------------------------------------------------
# mini conv library (NHWC)
# --------------------------------------------------------------------------
def _key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


def conv_init(keys, cin, cout, k=3, use_bias=False, groups=1):
    fan_in = (cin // groups) * k * k
    w = jax.random.normal(next(keys), (k, k, cin // groups, cout)) \
        * np.sqrt(2.0 / fan_in)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros((cout,))
    return p


def conv(p, x, stride=1, padding="SAME", groups=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if "b" in p:
        y = y + p["b"]
    return y


def bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def bn(p, x, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"]


def fc_init(keys, cin, cout):
    return {"w": jax.random.normal(next(keys), (cin, cout)) * np.sqrt(1.0 / cin),
            "b": jnp.zeros((cout,))}


def fc(p, x):
    return x @ p["w"] + p["b"]


def gap(x):                      # global average pool
    return jnp.mean(x, axis=(1, 2))


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def avgpool(x, k=2, s=2):
    out = jax.lax.reduce_window(x, 0.0, jax.lax.add,
                                (1, k, k, 1), (1, s, s, 1), "VALID")
    return out / (k * k)


relu = jax.nn.relu


def conv_bn_init(keys, cin, cout, k=3, groups=1):
    return {"conv": conv_init(keys, cin, cout, k, groups=groups),
            "bn": bn_init(cout)}


def conv_bn(p, x, stride=1, groups=1, act=True, padding="SAME"):
    y = bn(p["bn"], conv(p["conv"], x, stride, padding, groups))
    return relu(y) if act else y


# ==========================================================================
# 1. LeNet  (the paper's flat outlier)
# ==========================================================================
def lenet_init(key, n_classes=10):
    keys = _key_iter(key)
    return {"c1": conv_init(keys, 3, 6, 5, use_bias=True),
            "c2": conv_init(keys, 6, 16, 5, use_bias=True),
            "f1": fc_init(keys, 16 * 5 * 5, 120),
            "f2": fc_init(keys, 120, 84),
            "f3": fc_init(keys, 84, n_classes)}


def lenet_apply(p, x):
    x = maxpool(relu(conv(p["c1"], x, padding="VALID")))
    x = maxpool(relu(conv(p["c2"], x, padding="VALID")))
    x = x.reshape(x.shape[0], -1)
    return fc(p["f3"], relu(fc(p["f2"], relu(fc(p["f1"], x)))))


# ==========================================================================
# 2. VGG16
# ==========================================================================
_VGG16 = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
          512, 512, 512, "M", 512, 512, 512, "M"]


def vgg16_init(key, n_classes=10):
    keys = _key_iter(key)
    layers = []
    cin = 3
    for v in _VGG16:
        if v == "M":
            layers.append(None)
        else:
            layers.append(conv_bn_init(keys, cin, v))
            cin = v
    return {"layers": layers, "fc": fc_init(keys, 512, n_classes)}


def vgg16_apply(p, x):
    for spec, lp in zip(_VGG16, p["layers"]):
        x = maxpool(x) if spec == "M" else conv_bn(lp, x)
    return fc(p["fc"], gap(x))


# ==========================================================================
# 3/4. ResNet18 / PreActResNet18
# ==========================================================================
def _basic_block_init(keys, cin, cout, stride, preact=False):
    blk = {"c1": conv_bn_init(keys, cin, cout),
           "c2": conv_bn_init(keys, cout, cout)}
    if preact:
        # pre-activation: bn runs on the conv INPUT (cin / cout channels)
        blk["c1"]["bn"] = bn_init(cin)
    if stride != 1 or cin != cout:
        blk["short"] = conv_bn_init(keys, cin, cout, k=1)
    return blk


def _basic_block(p, x, stride, preact=False):
    if preact:
        h = relu(bn(p["c1"]["bn"], x))
        sc = conv(p["short"]["conv"], h, stride) if "short" in p else x
        h = conv(p["c1"]["conv"], h, stride)
        h = conv(p["c2"]["conv"], relu(bn(p["c2"]["bn"], h)))
        return h + sc
    h = conv_bn(p["c1"], x, stride)
    h = conv_bn(p["c2"], h, act=False)
    sc = conv_bn(p["short"], x, stride, act=False) if "short" in p else x
    return relu(h + sc)


_R18_SPEC = [(64, 1), (64, 1), (128, 2), (128, 1),
             (256, 2), (256, 1), (512, 2), (512, 1)]


def _resnet18_init(key, n_classes=10, *, preact=False):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 64), "blocks": [], "fc": None}
    cin = 64
    for cout, stride in _R18_SPEC:
        p["blocks"].append(_basic_block_init(keys, cin, cout, stride,
                                             preact=preact))
        cin = cout
    p["fc"] = fc_init(keys, 512, n_classes)
    return p


def _resnet18_apply(p, x, preact=False):
    x = conv_bn(p["stem"], x) if not preact else conv(p["stem"]["conv"], x)
    for blk, (_, stride) in zip(p["blocks"], _R18_SPEC):
        x = _basic_block(blk, x, stride, preact)
    return fc(p["fc"], gap(relu(x) if preact else x))


resnet18_init = functools.partial(_resnet18_init, preact=False)
resnet18_apply = functools.partial(_resnet18_apply, preact=False)
preactresnet18_init = functools.partial(_resnet18_init, preact=True)
preactresnet18_apply = functools.partial(_resnet18_apply, preact=True)


# ==========================================================================
# 5. SENet18 — ResNet18 with squeeze-excitation
# ==========================================================================
def senet18_init(key, n_classes=10):
    keys = _key_iter(key)
    p = _resnet18_init(key, n_classes, preact=False)
    p["se"] = []
    for cout in [64, 64, 128, 128, 256, 256, 512, 512]:
        p["se"].append({"f1": fc_init(keys, cout, cout // 16),
                        "f2": fc_init(keys, cout // 16, cout)})
    return p


def senet18_apply(p, x):
    x = conv_bn(p["stem"], x)
    for blk, (_, stride), se in zip(p["blocks"], _R18_SPEC, p["se"]):
        h = _basic_block(blk, x, stride)
        w = jax.nn.sigmoid(fc(se["f2"], relu(fc(se["f1"], gap(h)))))
        x = h * w[:, None, None, :]
    return fc(p["fc"], gap(x))


# ==========================================================================
# 6. GoogLeNet (inception)
# ==========================================================================
def _inception_init(keys, cin, n1, n3r, n3, n5r, n5, pp):
    return {"b1": conv_bn_init(keys, cin, n1, 1),
            "b2a": conv_bn_init(keys, cin, n3r, 1),
            "b2b": conv_bn_init(keys, n3r, n3, 3),
            "b3a": conv_bn_init(keys, cin, n5r, 1),
            "b3b": conv_bn_init(keys, n5r, n5, 3),
            "b3c": conv_bn_init(keys, n5, n5, 3),
            "b4": conv_bn_init(keys, cin, pp, 1)}


def _inception(p, x):
    b1 = conv_bn(p["b1"], x)
    b2 = conv_bn(p["b2b"], conv_bn(p["b2a"], x))
    b3 = conv_bn(p["b3c"], conv_bn(p["b3b"], conv_bn(p["b3a"], x)))
    pool = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 3, 3, 1), (1, 1, 1, 1), "SAME")
    b4 = conv_bn(p["b4"], pool)
    return jnp.concatenate([b1, b2, b3, b4], axis=-1)


_GOOGLE = [(192, 64, 96, 128, 16, 32, 32), (256, 128, 128, 192, 32, 96, 64),
           ("M",), (480, 192, 96, 208, 16, 48, 64),
           (512, 160, 112, 224, 24, 64, 64), (512, 128, 128, 256, 24, 64, 64),
           (512, 112, 144, 288, 32, 64, 64), (528, 256, 160, 320, 32, 128, 128),
           ("M",), (832, 256, 160, 320, 32, 128, 128),
           (832, 384, 192, 384, 48, 128, 128)]


def googlenet_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 192), "cells": []}
    for spec in _GOOGLE:
        if spec[0] == "M":
            p["cells"].append(None)
        else:
            p["cells"].append(_inception_init(keys, *spec))
    p["fc"] = fc_init(keys, 1024, n_classes)
    return p


def googlenet_apply(p, x):
    x = conv_bn(p["stem"], x)
    for spec, cell in zip(_GOOGLE, p["cells"]):
        x = maxpool(x, 3, 2) if spec[0] == "M" else _inception(cell, x)
    return fc(p["fc"], gap(x))


# ==========================================================================
# 7. DenseNet121
# ==========================================================================
_DN121 = [6, 12, 24, 16]


def densenet121_init(key, n_classes=10, growth=32):
    keys = _key_iter(key)
    cin = 2 * growth
    p = {"stem": conv_bn_init(keys, 3, cin), "blocks": [], "trans": []}
    for bi, n_layers in enumerate(_DN121):
        layers = []
        for _ in range(n_layers):
            layers.append({"c1": conv_bn_init(keys, cin, 4 * growth, 1),
                           "c2": conv_bn_init(keys, 4 * growth, growth, 3)})
            cin += growth
        p["blocks"].append(layers)
        if bi < len(_DN121) - 1:
            cout = cin // 2
            p["trans"].append(conv_bn_init(keys, cin, cout, 1))
            cin = cout
    p["fc"] = fc_init(keys, cin, n_classes)
    return p


def densenet121_apply(p, x):
    x = conv_bn(p["stem"], x)
    for bi, layers in enumerate(p["blocks"]):
        for lp in layers:
            h = conv_bn(lp["c2"], conv_bn(lp["c1"], x))
            x = jnp.concatenate([x, h], axis=-1)
        if bi < len(p["trans"]):
            x = avgpool(conv_bn(p["trans"][bi], x, act=False))
    return fc(p["fc"], gap(x))


# ==========================================================================
# 8. ResNeXt29 (2x64d)
# ==========================================================================
_RESNEXT_CARD = 2


def _resnext_spec(card=2, width=64):
    out, cin = [], 64
    for stage, stride0 in [(0, 1), (1, 2), (2, 2)]:
        group_w = card * width * (2 ** stage)
        cout = group_w * 2
        for i in range(3):
            out.append((cin, group_w, cout, stride0 if i == 0 else 1))
            cin = cout
    return out


def resnext29_init(key, n_classes=10, card=2, width=64):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 64), "blocks": []}
    for cin, group_w, cout, stride in _resnext_spec(card, width):
        blk = {"c1": conv_bn_init(keys, cin, group_w, 1),
               "c2": conv_bn_init(keys, group_w, group_w, 3, groups=card),
               "c3": conv_bn_init(keys, group_w, cout, 1)}
        if stride != 1 or cin != cout:
            blk["short"] = conv_bn_init(keys, cin, cout, 1)
        p["blocks"].append(blk)
    p["fc"] = fc_init(keys, cout, n_classes)
    return p


def resnext29_apply(p, x):
    x = conv_bn(p["stem"], x)
    for blk, (_, _, _, stride) in zip(p["blocks"], _resnext_spec()):
        h = conv_bn(blk["c1"], x)
        h = conv_bn(blk["c2"], h, stride, groups=_RESNEXT_CARD)
        h = conv_bn(blk["c3"], h, act=False)
        sc = conv_bn(blk["short"], x, stride, act=False) if "short" in blk else x
        x = relu(h + sc)
    return fc(p["fc"], gap(x))


# ==========================================================================
# 9/10. MobileNet / MobileNetV2
# ==========================================================================
def _dw_conv_init(keys, c, k=3):
    # depthwise: HWIO with I=1, groups=c
    fan_in = k * k
    w = jax.random.normal(next(keys), (k, k, 1, c)) * np.sqrt(2.0 / fan_in)
    return {"conv": {"w": w}, "bn": bn_init(c)}



_MBV1 = [64, (128, 2), 128, (256, 2), 256, (512, 2),
         512, 512, 512, 512, 512, (1024, 2), 1024]


def mobilenet_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 32), "blocks": []}
    cin = 32
    for v in _MBV1:
        cout, _ = (v, 1) if isinstance(v, int) else v
        p["blocks"].append({"dw": _dw_conv_init(keys, cin),
                            "pw": conv_bn_init(keys, cin, cout, 1)})
        cin = cout
    p["fc"] = fc_init(keys, 1024, n_classes)
    return p


def mobilenet_apply(p, x):
    x = conv_bn(p["stem"], x)
    for v, blk in zip(_MBV1, p["blocks"]):
        cout, stride = (v, 1) if isinstance(v, int) else v
        cin = x.shape[-1]
        # depthwise = grouped conv with groups = cin and 1 filter per group
        x = conv_bn(blk["dw"], x, stride, groups=cin)
        x = conv_bn(blk["pw"], x)
    return fc(p["fc"], gap(x))


_MBV2 = [(1, 16, 1, 1), (6, 24, 2, 1), (6, 32, 3, 2), (6, 64, 4, 2),
         (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]


def _inverted_residual_spec(spec_table):
    """Flatten (expand, cout, n, stride) stage specs into per-block
    (stride, residual?) statics."""
    out, cin = [], 32
    for t, c, n, s in spec_table:
        for i in range(n):
            stride = s if i == 0 else 1
            out.append((stride, stride == 1 and cin == c, cin * t))
            cin = c
    return out


def mobilenetv2_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 32), "blocks": []}
    cin = 32
    for t, c, n, s in _MBV2:
        for i in range(n):
            hid = cin * t
            p["blocks"].append({"expand": conv_bn_init(keys, cin, hid, 1),
                                "dw": _dw_conv_init(keys, hid),
                                "project": conv_bn_init(keys, hid, c, 1)})
            cin = c
    p["head"] = conv_bn_init(keys, cin, 1280, 1)
    p["fc"] = fc_init(keys, 1280, n_classes)
    return p


def mobilenetv2_apply(p, x):
    x = conv_bn(p["stem"], x)
    for blk, (stride, res, _) in zip(p["blocks"], _inverted_residual_spec(_MBV2)):
        h = conv_bn(blk["expand"], x)
        h = conv_bn(blk["dw"], h, stride, groups=h.shape[-1])
        h = conv_bn(blk["project"], h, act=False)
        x = x + h if res else h
    return fc(p["fc"], gap(conv_bn(p["head"], x)))


# ==========================================================================
# 11. ShuffleNetV2
# ==========================================================================
def _channel_shuffle(x, groups=2):
    B, H, W, C = x.shape
    return x.reshape(B, H, W, groups, C // groups).swapaxes(3, 4) \
            .reshape(B, H, W, C)


_SHUFFLE_V2 = [(116, 4), (232, 8), (464, 4)]


def shufflenetv2_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 24), "stages": []}
    cin = 24
    for cout, n in _SHUFFLE_V2:
        stage = []
        # downsample unit: both branches convolved
        stage.append({
            "b1dw": _dw_conv_init(keys, cin), "b1pw": conv_bn_init(keys, cin, cout // 2, 1),
            "b2pw1": conv_bn_init(keys, cin, cout // 2, 1),
            "b2dw": _dw_conv_init(keys, cout // 2),
            "b2pw2": conv_bn_init(keys, cout // 2, cout // 2, 1)})
        for _ in range(n - 1):
            half = cout // 2
            stage.append({
                "pw1": conv_bn_init(keys, half, half, 1),
                "dw": _dw_conv_init(keys, half),
                "pw2": conv_bn_init(keys, half, half, 1)})
        p["stages"].append(stage)
        cin = cout
    p["head"] = conv_bn_init(keys, cin, 1024, 1)
    p["fc"] = fc_init(keys, 1024, n_classes)
    return p


def shufflenetv2_apply(p, x):
    x = conv_bn(p["stem"], x)
    for stage in p["stages"]:
        d = stage[0]
        b1 = conv_bn(d["b1pw"], conv_bn(d["b1dw"], x, 2, groups=x.shape[-1], act=False))
        b2 = conv_bn(d["b2pw1"], x)
        b2 = conv_bn(d["b2dw"], b2, 2, groups=b2.shape[-1], act=False)
        b2 = conv_bn(d["b2pw2"], b2)
        x = _channel_shuffle(jnp.concatenate([b1, b2], -1))
        for blk in stage[1:]:
            x1, x2 = jnp.split(x, 2, axis=-1)
            h = conv_bn(blk["pw1"], x2)
            h = conv_bn(blk["dw"], h, groups=h.shape[-1], act=False)
            h = conv_bn(blk["pw2"], h)
            x = _channel_shuffle(jnp.concatenate([x1, h], -1))
    return fc(p["fc"], gap(conv_bn(p["head"], x)))


# ==========================================================================
# 12. EfficientNetB0
# ==========================================================================
_EFFB0 = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 40, 2, 2), (6, 80, 3, 2),
          (6, 112, 3, 1), (6, 192, 4, 2), (6, 320, 1, 1)]


def efficientnetb0_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 32), "blocks": []}
    cin = 32
    for t, c, n, s in _EFFB0:
        for i in range(n):
            hid = cin * t
            blk = {}
            if t != 1:
                blk["expand"] = conv_bn_init(keys, cin, hid, 1)
            blk["dw"] = _dw_conv_init(keys, hid)
            blk["se1"] = fc_init(keys, hid, max(1, cin // 4))
            blk["se2"] = fc_init(keys, max(1, cin // 4), hid)
            blk["project"] = conv_bn_init(keys, hid, c, 1)
            p["blocks"].append(blk)
            cin = c
    p["fc"] = fc_init(keys, cin, n_classes)
    return p


def efficientnetb0_apply(p, x):
    swish = jax.nn.silu
    x = swish(bn(p["stem"]["bn"], conv(p["stem"]["conv"], x)))
    statics = _inverted_residual_spec(_EFFB0)
    for blk, (stride, res, _) in zip(p["blocks"], statics):
        h = x
        if "expand" in blk:
            h = swish(bn(blk["expand"]["bn"], conv(blk["expand"]["conv"], h)))
        h = swish(bn(blk["dw"]["bn"],
                     conv(blk["dw"]["conv"], h, stride, groups=h.shape[-1])))
        w = jax.nn.sigmoid(fc(blk["se2"], swish(fc(blk["se1"], gap(h)))))
        h = h * w[:, None, None, :]
        h = bn(blk["project"]["bn"], conv(blk["project"]["conv"], h))
        x = x + h if res else h
    return fc(p["fc"], gap(x))


# ==========================================================================
# 13. RegNetX_200MF
# ==========================================================================
_REGX200 = [(24, 1, 8), (56, 1, 8), (152, 4, 8), (368, 7, 8)]  # (w, d, group)


def _regnet_spec():
    out, cin = [], 64
    for w, d, g in _REGX200:
        for i in range(d):
            stride = 1 if (i > 0 or w == 24) else 2
            out.append((cin, w, w // g, stride))
            cin = w
    return out


def regnetx200mf_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 64), "blocks": []}
    for cin, w, groups, stride in _regnet_spec():
        blk = {"c1": conv_bn_init(keys, cin, w, 1),
               "c2": conv_bn_init(keys, w, w, 3, groups=groups),
               "c3": conv_bn_init(keys, w, w, 1)}
        if stride != 1 or cin != w:
            blk["short"] = conv_bn_init(keys, cin, w, 1)
        p["blocks"].append(blk)
    p["fc"] = fc_init(keys, w, n_classes)
    return p


def regnetx200mf_apply(p, x):
    x = conv_bn(p["stem"], x)
    for blk, (_, _, groups, stride) in zip(p["blocks"], _regnet_spec()):
        h = conv_bn(blk["c1"], x)
        h = conv_bn(blk["c2"], h, stride, groups=groups)
        h = conv_bn(blk["c3"], h, act=False)
        sc = conv_bn(blk["short"], x, stride, act=False) \
            if "short" in blk else x
        x = relu(h + sc)
    return fc(p["fc"], gap(x))


# ==========================================================================
# 14. DPN92 (dual path network, CIFAR variant)
# ==========================================================================
_DPN92 = [(96, 256, 16, 3, 1), (192, 512, 32, 4, 2),
          (384, 1024, 24, 20, 2), (768, 2048, 128, 3, 2)]


def _dpn_spec():
    out, cin = [], 64
    for in_planes, out_planes, dense_depth, n, stride0 in _DPN92:
        for i in range(n):
            out.append((cin, in_planes, out_planes, dense_depth,
                        stride0 if i == 0 else 1, i == 0))
            cin = out_planes + (i + 2) * dense_depth
    return out, cin


def dpn92_init(key, n_classes=10):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, 64), "blocks": []}
    spec, c_final = _dpn_spec()
    for cin, in_planes, out_planes, dense_depth, stride, first in spec:
        blk = {"c1": conv_bn_init(keys, cin, in_planes, 1),
               "c2": conv_bn_init(keys, in_planes, in_planes, 3, groups=32),
               "c3": conv_bn_init(keys, in_planes,
                                  out_planes + dense_depth, 1)}
        if first:    # dual-path: conv shortcut only opens each stage
            blk["short"] = conv_bn_init(keys, cin,
                                        out_planes + dense_depth, 1)
        p["blocks"].append(blk)
    p["fc"] = fc_init(keys, c_final, n_classes)
    return p


def dpn92_apply(p, x):
    x = conv_bn(p["stem"], x)
    spec, _ = _dpn_spec()
    for blk, (_, _, out, d, stride, first) in zip(p["blocks"], spec):
        h = conv_bn(blk["c1"], x)
        h = conv_bn(blk["c2"], h, stride, groups=32)
        h = conv_bn(blk["c3"], h, act=False)
        sc = conv_bn(blk["short"], x, stride, act=False) if first else x
        # dual path: residual add on the first `out` channels, dense-style
        # concat growth on the rest (accumulates +d per block)
        res = sc[..., :out] + h[..., :out]
        dense = jnp.concatenate([sc[..., out:], h[..., out:]], axis=-1)
        x = relu(jnp.concatenate([res, dense], axis=-1))
    return fc(p["fc"], gap(x))


# ==========================================================================
# 15. SimpleDLA (deep layer aggregation, simplified)
# ==========================================================================
def simpledla_init(key, n_classes=10):
    keys = _key_iter(key)
    widths = [16, 32, 64, 128, 256, 512]
    p = {"stem": conv_bn_init(keys, 3, 16), "stages": []}
    cin = 16
    for w in widths:
        stage = {"b1": _basic_block_init(keys, cin, w, 1),
                 "b2": _basic_block_init(keys, w, w, 1),
                 "agg": conv_bn_init(keys, 2 * w, w, 1)}
        p["stages"].append(stage)
        cin = w
    p["fc"] = fc_init(keys, cin, n_classes)
    return p


def simpledla_apply(p, x):
    x = conv_bn(p["stem"], x)
    for i, stage in enumerate(p["stages"]):
        h1 = _basic_block(stage["b1"], x, 1)
        h2 = _basic_block(stage["b2"], h1, 1)
        x = conv_bn(stage["agg"], jnp.concatenate([h1, h2], -1))
        if i >= 2:
            x = maxpool(x)
    return fc(p["fc"], gap(x))


# ==========================================================================
# 16. PNASNet (reduced: PNASNetA cell, CIFAR)
# ==========================================================================
def _pnas_spec(f=44):
    out, cin = [], f
    for stage in range(3):
        cout = f * (2 ** stage)
        n_cells = 6 if stage < 2 else 5
        for i in range(n_cells):
            stride = 2 if (stage > 0 and i == 0) else 1
            out.append((cin, cout, stride))
            cin = cout
    return out


def pnasnet_init(key, n_classes=10, f=44):
    keys = _key_iter(key)
    p = {"stem": conv_bn_init(keys, 3, f), "cells": []}
    for cin, cout, stride in _pnas_spec(f):
        cell = {"sep": _dw_conv_init(keys, cin, 7 if stride == 2 else 5),
                "pw": conv_bn_init(keys, cin, cout, 1)}
        if stride == 2 or cin != cout:
            cell["short"] = conv_bn_init(keys, cin, cout, 1)
        p["cells"].append(cell)
    p["fc"] = fc_init(keys, cout, n_classes)
    return p


def pnasnet_apply(p, x):
    x = conv_bn(p["stem"], x)
    for cell, (_, _, stride) in zip(p["cells"], _pnas_spec()):
        h = conv_bn(cell["sep"], x, stride, groups=x.shape[-1], act=False)
        h = conv_bn(cell["pw"], h, act=False)
        sc = conv_bn(cell["short"], x, stride, act=False) \
            if "short" in cell else x
        x = relu(h + sc)
    return fc(p["fc"], gap(x))


# ==========================================================================
# registry — the paper's 16 models
# ==========================================================================
CNN_ZOO: dict[str, tuple[Callable, Callable]] = {
    "SimpleDLA": (simpledla_init, simpledla_apply),
    "DPN92": (dpn92_init, dpn92_apply),
    "DenseNet121": (densenet121_init, densenet121_apply),
    "EfficientNetB0": (efficientnetb0_init, efficientnetb0_apply),
    "GoogLeNet": (googlenet_init, googlenet_apply),
    "LeNet": (lenet_init, lenet_apply),
    "MobileNet": (mobilenet_init, mobilenet_apply),
    "MobileNetV2": (mobilenetv2_init, mobilenetv2_apply),
    "PNASNet": (pnasnet_init, pnasnet_apply),
    "PreActResNet18": (preactresnet18_init, preactresnet18_apply),
    "RegNetX_200MF": (regnetx200mf_init, regnetx200mf_apply),
    "ResNet18": (resnet18_init, resnet18_apply),
    "ResNeXt29_2x64d": (resnext29_init, resnext29_apply),
    "SENet18": (senet18_init, senet18_apply),
    "ShuffleNetV2": (shufflenetv2_init, shufflenetv2_apply),
    "VGG16": (vgg16_init, vgg16_apply),
}


def cnn_loss(apply_fn, params, images, labels):
    logits = apply_fn(params, images)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
