"""The LM trunk: init / train-forward / prefill / decode for every assigned
architecture family.

Layers are stacked into *pattern units* and iterated with ``lax.scan`` so the
HLO stays O(1) in depth (46-layer gemma2 compiles as fast as 2 layers):

  * dense / moe / ssm:  unit = 1 layer, scan over n_layers.
  * gemma2 (local/global alternation): unit = 2 layers (sub0 local window,
    sub1 global) — both sublayers are distinct programs in the scan body, so
    compiled FLOPs are honest (no lax.cond double-counting).
  * deepseek (first layer dense): layer 0 unrolled, units = remaining layers.
  * zamba2: unit = ``hybrid_attn_every`` mamba layers + ONE application of a
    *shared* attention block (single param copy, closed over by the scan
    body; Zamba2's core trick).

Each apply function takes a ``RunCtx`` carrying the mesh context, kernel
policy, activation-sharding ``constrain`` hook, and remat policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import quant
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import common, ssm
from repro.models.attention import ParamLeaf, pl_, split_leaves
from repro.models.config import ModelConfig
from repro.models.layers import NO_MESH, ParallelCtx, init_mlp, init_moe, \
    mlp_forward, moe_forward

VOCAB_PAD = 256


def padded_vocab(cfg: ModelConfig) -> int:
    v = cfg.vocab_size
    return (v + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


@dataclasses.dataclass(frozen=True)
class RunCtx:
    parallel: ParallelCtx = NO_MESH
    kernel_policy: ops.KernelPolicy = ops.DEFAULT_POLICY
    constrain: Callable[[jax.Array, tuple], jax.Array] | None = None
    remat: str = "none"                 # none | full | dots
    decode_cache_len: int = 0           # 0 -> cfg.max_seq_len


def unit_size(cfg: ModelConfig) -> int:
    if cfg.local_global:
        return 2
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        return cfg.hybrid_attn_every
    return 1


def n_units(cfg: ModelConfig) -> int:
    u = unit_size(cfg)
    body_layers = cfg.n_layers - cfg.first_dense_layers
    if body_layers % u:
        raise ValueError(f"{cfg.name}: {body_layers} layers not divisible by "
                         f"pattern unit {u}")
    return body_layers // u


# ==========================================================================
# init
# ==========================================================================
def _init_block(key, cfg: ModelConfig, *, moe: bool, d_ff: int | None = None):
    """One transformer sublayer: norm -> attn -> norm -> ffn."""
    k1, k2 = common.split_keys(key, 2)
    dt = cfg.param_dtype
    blk: dict[str, Any] = {
        "norm1": ParamLeaf(_norm_init(cfg), (None,)),
        "attn": (attn.init_mla(k1, cfg) if cfg.use_mla else attn.init_gqa(k1, cfg)),
        "norm2": ParamLeaf(_norm_init(cfg), (None,)),
        "ffn": (init_moe(k2, cfg) if moe else init_mlp(k2, cfg, d_ff)),
    }
    if cfg.post_norms:
        blk["post_attn_norm"] = ParamLeaf(_norm_init(cfg), (None,))
        blk["post_ffn_norm"] = ParamLeaf(_norm_init(cfg), (None,))
    return blk


def _norm_init(cfg: ModelConfig):
    # gemma stores (1 + w): init w at 0; others init scale at 1
    if cfg.post_norms:
        return common.zeros((cfg.d_model,), cfg.param_dtype)
    return common.ones((cfg.d_model,), cfg.param_dtype)


def _init_unit(key, cfg: ModelConfig):
    """One pattern unit (see module docstring)."""
    u = unit_size(cfg)
    keys = common.split_keys(key, u)
    unit: dict[str, Any] = {}
    for i in range(u):
        if cfg.uses_ssm:
            unit[f"sub{i}"] = ssm.init_mamba(keys[i], cfg)
        else:
            unit[f"sub{i}"] = _init_block(keys[i], cfg, moe=cfg.uses_moe)
    return unit


def _init_shared_attn(key, cfg: ModelConfig):
    """Zamba2's shared block: input = concat(hidden, embeddings) -> proj to
    d -> attn + MLP -> residual add into the trunk."""
    k0, k1 = common.split_keys(key, 2)
    d = cfg.d_model
    return {
        "w_in": pl_(k0, (2 * d, d), ("embed", "embed_out"), dtype=cfg.param_dtype),
        "block": _init_block(k1, cfg, moe=False),
    }


def init_lm(key, cfg: ModelConfig):
    """Returns (params, logical_axes) raw trees (ParamLeaf already split)."""
    keys = common.split_keys(key, 8)
    Vp = padded_vocab(cfg)
    d = cfg.d_model
    dt = cfg.param_dtype
    tree: dict[str, Any] = {}

    if cfg.n_codebooks:
        tree["embed"] = pl_(keys[0], (cfg.n_codebooks, Vp, d),
                            (None, "vocab", "embed"), std=0.02, dtype=dt)
        tree["lm_head"] = pl_(keys[1], (cfg.n_codebooks, d, Vp),
                              (None, "embed", "vocab"), std=0.02, dtype=dt)
    else:
        tree["embed"] = pl_(keys[0], (Vp, d), ("vocab", "embed"),
                            std=0.02, dtype=dt)
        if not cfg.tie_embeddings:
            tree["lm_head"] = pl_(keys[1], (d, Vp), ("embed", "vocab"),
                                  std=0.02, dtype=dt)

    if cfg.first_dense_layers:
        dense_keys = common.split_keys(keys[2], cfg.first_dense_layers)
        tree["dense_layers"] = [
            _init_block(dk, cfg, moe=False, d_ff=cfg.dense_d_ff or cfg.d_ff)
            for dk in dense_keys]

    nu = n_units(cfg)
    unit_keys = jax.random.split(keys[3], nu)
    stacked = jax.vmap(functools.partial(_init_unit, cfg=cfg))(unit_keys)
    # prepend the stacked "layers" axis to every leaf's logical axes
    is_leaf = lambda x: isinstance(x, ParamLeaf)
    stacked = jax.tree.map(
        lambda l: ParamLeaf(l.array, ("layers",) + tuple(l.axes)),
        stacked, is_leaf=is_leaf)
    tree["layers"] = stacked

    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        tree["shared_attn"] = _init_shared_attn(keys[4], cfg)

    tree["final_norm"] = ParamLeaf(_norm_init(cfg), (None,))
    return split_leaves(tree)


# ==========================================================================
# sublayer application
# ==========================================================================
def _norm(x, scale, cfg: ModelConfig):
    return common.rmsnorm(x, scale, cfg.norm_eps, gemma_style=cfg.post_norms)


def _apply_block(blk, x, positions, cfg: ModelConfig, ctx: RunCtx, *,
                 window: int, aux: jax.Array):
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla:
        a = attn.mla_forward(blk["attn"], h, positions, cfg,
                             policy=ctx.kernel_policy, constrain=ctx.constrain)
    else:
        a = attn.gqa_forward(blk["attn"], h, positions, cfg, window=window,
                             policy=ctx.kernel_policy, constrain=ctx.constrain)
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, aux_l = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                               constrain=ctx.constrain)
        aux = aux + aux_l
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, aux


def _apply_shared_attn(shared, x, emb0, positions, cfg: ModelConfig,
                       ctx: RunCtx, aux):
    h = jnp.concatenate([x, emb0], axis=-1) @ shared["w_in"].astype(x.dtype)
    out, aux = _apply_block(shared["block"], h, positions, cfg, ctx,
                            window=0, aux=aux)
    return x + (out - h), aux    # residual delta of the shared block


def _apply_unit(unit, x, emb0, positions, cfg: ModelConfig, ctx: RunCtx,
                shared, aux):
    u = unit_size(cfg)
    for i in range(u):
        sub = unit[f"sub{i}"]
        if cfg.uses_ssm:
            h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
            x = x + ssm.mamba_forward(sub, h, cfg, policy=ctx.kernel_policy,
                                      constrain=ctx.constrain)
        else:
            window = cfg.window_for_layer(i)
            x, aux = _apply_block(sub, x, positions, cfg, ctx,
                                  window=window, aux=aux)
    if shared is not None:
        x, aux = _apply_shared_attn(shared, x, emb0, positions, cfg, ctx, aux)
    return x, aux


# ==========================================================================
# embedding / head
# ==========================================================================
def embed_tokens(params, tokens, cfg: ModelConfig, ctx: RunCtx):
    adt = common.dt(cfg.dtype)
    if cfg.n_codebooks:
        # tokens: (B, S, n_cb) — sum of per-codebook embeddings
        embs = params["embed"].astype(adt)          # (n_cb, Vp, d)
        x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), adt)
        for c in range(cfg.n_codebooks):
            x = x + embs[c][tokens[..., c]]
    else:
        x = params["embed"].astype(adt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, adt)
    if ctx.constrain is not None:
        x = ctx.constrain(x, ("batch", None, "embed_act"))
    return x


def lm_logits(params, x, cfg: ModelConfig, ctx: RunCtx):
    adt = x.dtype
    Vp = padded_vocab(cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"].astype(adt))
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].astype(adt).T
    else:
        logits = x @ params["lm_head"].astype(adt)
    if cfg.final_logit_softcap > 0.0:
        logits = common.softcap(logits, cfg.final_logit_softcap)
    # mask the padded vocab tail
    if Vp != cfg.vocab_size:
        mask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    if ctx.constrain is not None:
        spec = ("batch", None, None, "vocab") if cfg.n_codebooks \
            else ("batch", None, "vocab")
        logits = ctx.constrain(logits, spec)
    return logits


# ==========================================================================
# full forward (training)
# ==========================================================================
def forward(params, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(), *,
            extra_embeds: jax.Array | None = None):
    """Token ids -> logits.  ``extra_embeds`` (B, n_img, d) is the LLaVA
    vision prefix (precomputed patch embeddings; frontend is a stub)."""
    x = embed_tokens(params, tokens, cfg, ctx)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    emb0 = x
    aux0 = jnp.zeros((), jnp.float32)

    for dense_blk in params.get("dense_layers", []):
        x, aux0 = _apply_block(dense_blk, x, positions, cfg, ctx,
                               window=cfg.sliding_window, aux=aux0)

    shared = params.get("shared_attn")

    def body(carry, unit):
        x, aux = carry
        x, aux = _apply_unit(unit, x, emb0, positions, cfg, ctx, shared, aux)
        return (x, aux), None

    if ctx.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif ctx.remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)
    return logits, aux


def lm_loss(params, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(), *,
            extra_embeds: jax.Array | None = None):
    """Next-token CE (+ MoE aux).  For multi-codebook audio, the loss is the
    mean CE over codebooks; for VLM, image-prefix positions carry no loss."""
    if cfg.n_codebooks:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        logits, aux = forward(params, inputs, cfg, ctx)
        losses = [common.cross_entropy(logits[:, :, c], labels[..., c])
                  for c in range(cfg.n_codebooks)]
        return sum(losses) / cfg.n_codebooks + aux
    inputs, labels, mask = common.shift_labels(tokens)
    logits, aux = forward(params, inputs, cfg, ctx, extra_embeds=extra_embeds)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    return common.cross_entropy(logits, labels, mask) + aux


def lm_loss_pre_shifted(params, inputs, targets, cfg: ModelConfig,
                        ctx: RunCtx = RunCtx(), *,
                        extra_embeds: jax.Array | None = None):
    """CE with a pre-shifted (inputs, targets) pair — the production data
    pipeline emits these so the step sees clean power-of-two seq lengths."""
    logits, aux = forward(params, inputs, cfg, ctx, extra_embeds=extra_embeds)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    if cfg.n_codebooks:
        losses = [common.cross_entropy(logits[:, :, c], targets[..., c])
                  for c in range(cfg.n_codebooks)]
        return sum(losses) / cfg.n_codebooks + aux
    return common.cross_entropy(logits, targets) + aux


# ==========================================================================
# prefill / decode
# ==========================================================================
def _cache_len(cfg: ModelConfig, ctx: RunCtx, seq_len: int, window: int) -> int:
    cap = ctx.decode_cache_len or max(cfg.max_seq_len, seq_len)
    if window > 0:
        cap = min(cap, window)
    return cap


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: str = "bfloat16"):
    """Zero-filled decode cache pytree (+ its logical sharding axes)."""
    adt = common.dt(dtype)
    hd = cfg.resolved_head_dim
    nu, u = n_units(cfg), unit_size(cfg)

    def attn_cache(cap):
        if cfg.use_mla:
            return {"lat": jnp.zeros(
                (nu, batch, cap, cfg.kv_lora_rank + cfg.rope_head_dim), adt)}
        hkv = cfg.padded_kv_heads
        return {"k": jnp.zeros((nu, batch, cap, hkv, hd), adt),
                "v": jnp.zeros((nu, batch, cap, hkv, hd), adt)}

    def mamba_cache():
        cd = ssm.conv_dim(cfg)
        H, P_, N = cfg.resolved_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        return {"conv": jnp.zeros((nu, batch, cfg.conv_width - 1, cd), adt),
                "ssm": jnp.zeros((nu, batch, H, P_, N), jnp.float32)}

    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    units: dict[str, Any] = {}
    for i in range(u):
        if cfg.uses_ssm:
            units[f"sub{i}"] = mamba_cache()
        else:
            w = cfg.window_for_layer(i)
            cap = min(max_len, w) if w > 0 else max_len
            # MLA caches have no per-head dim; GQA caches are per-kv-head
            c = attn_cache(cap)
            units[f"sub{i}"] = c
    cache["units"] = units
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        cap = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        cache["shared"] = {
            "k": jnp.zeros((nu, batch, cap, cfg.padded_kv_heads, hd), adt),
            "v": jnp.zeros((nu, batch, cap, cfg.padded_kv_heads, hd), adt)}
    if cfg.first_dense_layers:
        cap = max_len
        dc = []
        for _ in range(cfg.first_dense_layers):
            if cfg.use_mla:
                dc.append({"lat": jnp.zeros(
                    (batch, cap, cfg.kv_lora_rank + cfg.rope_head_dim), adt)})
            else:
                hkv = cfg.padded_kv_heads
                dc.append({"k": jnp.zeros((batch, cap, hkv, hd), adt),
                           "v": jnp.zeros((batch, cap, hkv, hd), adt)})
        cache["dense"] = dc
    return cache


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """The paged layout covers the GQA attention families (dense / MoE /
    multi-codebook).  SSM state is O(1) per slot (nothing to page), MLA
    caches latents not k/v heads, and sliding-window / hybrid layouts need a
    per-layer table — all natural follow-ons, rejected loudly for now."""
    return (not cfg.uses_ssm and not cfg.use_mla
            and not cfg.first_dense_layers and not cfg.local_global
            and cfg.sliding_window == 0
            and not (cfg.family == "hybrid" and cfg.hybrid_attn_every))


def init_paged_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                     page_size: int, max_blocks: int,
                     dtype: str = "bfloat16"):
    """Zero-filled paged decode cache: per-unit page *pools* shared by every
    slot, one block table and one position counter per slot.

    Layout per attention unit: k/v pools (n_units, n_pages, page_size, Hkv,
    hd).  ``block_tables[s, j]`` is the physical page holding slot s's
    logical block j (positions [j*ps, (j+1)*ps)); the engine parks free
    slots on a reserved per-slot scratch page so decode needs no validity
    branch.  ``pos`` is per-slot — the batch is ragged by construction.

    ``dtype="int8"`` selects the quantized storage mode: int8 pools plus
    per-ROW-per-kv-head fp32 scale leaves ``k_scale``/``v_scale`` of shape
    (n_units, n_pages, page_size, Hkv, 1).  Rows are quantized at write
    time (decode scatter / speculative commit) and dequantized inside the
    attention sweep; a row, once written, never rescales, so page-level
    sharing and snapshots stay bit-stable.  The cache *structure* carries
    the mode — downstream seams discriminate on ``"k_scale" in unit``,
    which is static under jit."""
    if not supports_paged_cache(cfg):
        raise ValueError(f"{cfg.name}: paged KV cache supports dense GQA "
                         "families only (no ssm/mla/window/hybrid)")
    quantized = dtype == "int8"
    adt = jnp.int8 if quantized else common.dt(dtype)
    hd = cfg.resolved_head_dim
    nu, u = n_units(cfg), unit_size(cfg)
    hkv = cfg.padded_kv_heads
    units = {
        f"sub{i}": {
            "k": jnp.zeros((nu, n_pages, page_size, hkv, hd), adt),
            "v": jnp.zeros((nu, n_pages, page_size, hkv, hd), adt)}
        for i in range(u)
    }
    if quantized:
        for sub in units.values():
            sub["k_scale"] = jnp.zeros((nu, n_pages, page_size, hkv, 1),
                                       jnp.float32)
            sub["v_scale"] = jnp.zeros((nu, n_pages, page_size, hkv, 1),
                                       jnp.float32)
    return {"pos": jnp.zeros((n_slots,), jnp.int32),
            "block_tables": jnp.zeros((n_slots, max_blocks), jnp.int32),
            "units": units}


def _block_prefill(blk, x, positions, cfg: ModelConfig, ctx: RunCtx, *,
                   window: int, cache_len: int, aux):
    """_apply_block that also emits this layer's decode cache."""
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla:
        a, lat = attn.mla_prefill(blk["attn"], h, positions, cfg,
                                  cache_len=cache_len,
                                  policy=ctx.kernel_policy,
                                  constrain=ctx.constrain)
        c = {"lat": lat}
    else:
        a, (k, v) = attn.gqa_prefill(blk["attn"], h, positions, cfg,
                                     window=window, cache_len=cache_len,
                                     policy=ctx.kernel_policy,
                                     constrain=ctx.constrain)
        c = {"k": k, "v": v}
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, aux_l = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                               constrain=ctx.constrain)
        aux = aux + aux_l
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, c, aux


def prefill(params, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(), *,
            max_len: int = 0, extra_embeds: jax.Array | None = None):
    """Process the full prompt and build the decode cache.

    Returns (logits, cache) — logits for every prompt position (the serving
    layer samples from the last one); cache['pos'] = prompt length.
    """
    x = embed_tokens(params, tokens, cfg, ctx)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    max_len = max_len or max(cfg.max_seq_len, S)
    positions = jnp.arange(S)[None, :]
    emb0 = x
    aux = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")

    dense_cache = []
    for blk in params.get("dense_layers", []):
        cap = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        x, c, aux = _block_prefill(blk, x, positions, cfg, ctx,
                                   window=cfg.sliding_window,
                                   cache_len=cap, aux=aux)
        dense_cache.append(c)

    def body(carry, unit):
        x, aux = carry
        u = unit_size(cfg)
        unit_cache = {}
        for i in range(u):
            sub = unit[f"sub{i}"]
            if cfg.uses_ssm:
                h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
                out, (conv, ssm_state) = ssm.mamba_forward(
                    sub, h, cfg, policy=ctx.kernel_policy,
                    constrain=ctx.constrain, return_state=True)
                x = x + out
                unit_cache[f"sub{i}"] = {"conv": conv, "ssm": ssm_state}
            else:
                w = cfg.window_for_layer(i)
                cap = min(max_len, w) if w > 0 else max_len
                x, c, aux = _block_prefill(sub, x, positions, cfg, ctx,
                                           window=w, cache_len=cap, aux=aux)
                unit_cache[f"sub{i}"] = c
        if shared is not None:
            h = jnp.concatenate([x, emb0], axis=-1) @ shared["w_in"].astype(x.dtype)
            out, c, aux = _block_prefill(shared["block"], h, positions, cfg,
                                         ctx, window=0, cache_len=max_len,
                                         aux=aux)
            x = x + (out - h)
            unit_cache["__shared__"] = c
        return (x, aux), unit_cache

    (x, aux), unit_caches = jax.lax.scan(body, (x, aux), params["layers"])
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)

    cache = {"pos": jnp.asarray(S, jnp.int32),
             "units": {k: v for k, v in unit_caches.items()
                       if k != "__shared__"}}
    if shared is not None:
        cache["shared"] = unit_caches["__shared__"]
    if dense_cache:
        cache["dense"] = dense_cache
    return logits, cache


def _block_decode(blk, x, pos, c, cfg: ModelConfig, ctx: RunCtx, *,
                  window: int, block_tables: jax.Array | None = None):
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla:
        a, lat = attn.mla_decode(blk["attn"], h, pos, c["lat"], cfg,
                                 constrain=ctx.constrain)
        c = {"lat": lat}
    elif block_tables is not None:
        if "k_scale" in c:       # int8 pools: thread the scale leaves
            kv_in = (c["k"], c["v"], c["k_scale"], c["v_scale"])
            a, kv_out = attn.gqa_decode_paged(blk["attn"], h, pos, kv_in,
                                              block_tables, cfg,
                                              window=window,
                                              policy=ctx.kernel_policy,
                                              constrain=ctx.constrain)
            c = dict(zip(("k", "v", "k_scale", "v_scale"), kv_out))
        else:
            a, (k, v) = attn.gqa_decode_paged(blk["attn"], h, pos,
                                              (c["k"], c["v"]), block_tables,
                                              cfg, window=window,
                                              policy=ctx.kernel_policy,
                                              constrain=ctx.constrain)
            c = {"k": k, "v": v}
    else:
        a, (k, v) = attn.gqa_decode(blk["attn"], h, pos, (c["k"], c["v"]),
                                    cfg, window=window,
                                    policy=ctx.kernel_policy,
                                    constrain=ctx.constrain)
        c = {"k": k, "v": v}
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, _ = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                           constrain=ctx.constrain)
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, c


def _paged_decode_step(params, cache, tokens, cfg: ModelConfig, ctx: RunCtx,
                       active: jax.Array | None):
    """decode_step over the paged cache layout: per-slot positions, block
    tables, shared page pools.  ``active`` (B,) gates the position advance —
    parked slots keep rewriting row ``pos[b]`` of their scratch page and
    their sampled tokens are discarded by the engine, so one executable
    serves every occupancy pattern."""
    pos = cache["pos"]                                     # (B,)
    bt = cache["block_tables"]
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, xs):
        unit, c_unit = xs
        new_c = {}
        for i in range(unit_size(cfg)):
            sub, c = unit[f"sub{i}"], c_unit[f"sub{i}"]
            x, c2 = _block_decode(sub, x, pos, c, cfg, ctx, window=0,
                                  block_tables=bt)
            new_c[f"sub{i}"] = c2
        return x, new_c

    x, new_units = jax.lax.scan(body, x, (params["layers"], cache["units"]))
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)
    adv = jnp.ones_like(pos) if active is None \
        else jnp.asarray(active, jnp.int32)
    new_cache = {"pos": pos + adv, "block_tables": bt, "units": new_units}
    return logits, new_cache


def supports_speculative(cfg: ModelConfig) -> bool:
    """Speculative verify covers the GQA attention families (dense / MoE /
    local-global / sliding-window).  SSM recurrence would need per-step
    state snapshots to roll back, MLA decode runs an absorbed custom path,
    multi-codebook drafts would have to match on every codebook, and the
    hybrid shared block carries its own cache — all follow-ons, rejected
    loudly for now."""
    return (not cfg.uses_ssm and not cfg.use_mla and not cfg.n_codebooks
            and not cfg.first_dense_layers
            and not (cfg.family == "hybrid" and cfg.hybrid_attn_every))


def _block_verify(blk, x, pos, c, cfg: ModelConfig, ctx: RunCtx, *,
                  window: int, block_tables: jax.Array | None = None):
    """_block_decode's speculative sibling: scores the whole fed block in
    one cache sweep and returns this layer's *pending* k/v rows instead of
    writing the cache."""
    h = _norm(x, blk["norm1"], cfg)
    if block_tables is not None:
        kv_in = ((c["k"], c["v"], c["k_scale"], c["v_scale"])
                 if "k_scale" in c else (c["k"], c["v"]))
        a, kv_new = attn.gqa_verify_paged(blk["attn"], h, pos, kv_in,
                                          block_tables,
                                          cfg, window=window,
                                          policy=ctx.kernel_policy,
                                          constrain=ctx.constrain)
    else:
        a, kv_new = attn.gqa_verify(blk["attn"], h, pos, (c["k"], c["v"]),
                                    cfg, window=window,
                                    policy=ctx.kernel_policy,
                                    constrain=ctx.constrain)
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, _ = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                           constrain=ctx.constrain)
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, {"k": kv_new[0], "v": kv_new[1]}


def verify_step(params, cache, tokens, cfg: ModelConfig,
                ctx: RunCtx = RunCtx()):
    """Score ``Q = K+1`` speculative tokens in ONE cache sweep.

    tokens: (B, Q) — the fed block [t_last, d_1..d_K] at positions
    ``pos .. pos+Q-1``.  Returns (logits (B, Q, V), pending) where
    ``pending`` mirrors ``cache['units']`` with per-layer candidate k/v
    rows of shape (n_units, B, Q, Hkv, hd) — NOTHING is committed past the
    accepted prefix until :func:`commit_spec` / :func:`commit_spec_paged`
    scatters rows ``0..n_accept`` and advances ``pos``.  Both cache
    layouts share this seam, discriminated by pytree structure exactly
    like ``decode_step``."""
    if not supports_speculative(cfg):
        raise ValueError(f"{cfg.name}: speculative decode supports dense "
                         "GQA families only (no ssm/mla/codebooks/hybrid)")
    paged = "block_tables" in cache
    pos = cache["pos"]                  # () ring | (B,) paged
    bt = cache.get("block_tables")
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, xs):
        unit, c_unit = xs
        pend = {}
        for i in range(unit_size(cfg)):
            sub, c = unit[f"sub{i}"], c_unit[f"sub{i}"]
            window = 0 if paged else cfg.window_for_layer(i)
            x, p = _block_verify(sub, x, pos, c, cfg, ctx, window=window,
                                 block_tables=bt)
            pend[f"sub{i}"] = p
        return x, pend

    x, pending = jax.lax.scan(body, x, (params["layers"], cache["units"]))
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)
    return logits, pending


def commit_spec(cache, pending, n_accept, cfg: ModelConfig):
    """Commit the accepted prefix of a verify step into the ring cache.

    ``pending`` holds rows for the fed block [t_last, d_1..d_K]; rows
    ``0..n_accept`` (t_last plus the accepted drafts) scatter into slots
    ``(pos + i) % C`` and ``pos`` advances by ``n_accept + 1``.  Rejected
    rows route to an out-of-bounds slot and are dropped — the ring's
    history is never touched past the accepted prefix, so there is nothing
    to roll back.  ``n_accept`` is a traced scalar: ONE executable serves
    every acceptance pattern inside the fused scan."""
    pos = cache["pos"]
    new_units = {}
    for name, c in cache["units"].items():
        pend = pending[name]
        Q = pend["k"].shape[2]
        C = c["k"].shape[2]
        i = jnp.arange(Q)
        slots = jnp.where(i <= n_accept, (pos + i) % C, C)   # C is OOB
        new_units[name] = {
            key: c[key].at[:, :, slots].set(
                pend[key].astype(c[key].dtype), mode="drop")
            for key in ("k", "v")}
    return {"pos": pos + n_accept + 1, "units": new_units}


def prefill_suffix(params, cache, tokens, n_commit, cfg: ModelConfig,
                   ctx: RunCtx = RunCtx()):
    """Chunked paged prefill: score a block of *prompt suffix* tokens
    against a slot's already-cached prefix and commit their k/v.

    This is the prefix-sharing engine's join path: when
    ``PagedKVCache.admit_with_prefix`` maps a cached prefix of length
    ``m``, only ``tokens[m:]`` need compute — and scoring a suffix chunk
    at positions ``pos .. pos+Q-1`` against pages committed through
    ``pos-1`` is *exactly* the speculative verify sweep with
    ``q_len = chunk`` (``ops.paged_verify_attention`` — no new kernel).
    The commit is the speculative commit with every real row accepted:
    ``n_commit`` (B,) counts each slot's real (non-pad) rows this chunk;
    rows ``0..n_commit-1`` scatter through the block table, ``pos``
    advances by ``n_commit``, and slots with ``n_commit == 0`` neither
    write nor advance — so one fixed-shape executable serves every join
    against the live engine cache without touching the other slots.

    Returns ``(logits, cache)``: row ``n_commit[b] - 1`` of slot b's
    logits scores the token after its last real suffix token (the
    engine's first-token sample on a full-suffix join)."""
    logits, pending = verify_step(params, cache, tokens, cfg, ctx)
    active = (n_commit > 0).astype(jnp.int32)
    new_cache = commit_spec_paged(cache, pending, n_commit - 1, active, cfg)
    return logits, new_cache


def commit_spec_paged(cache, pending, n_accept, active, cfg: ModelConfig):
    """Paged commit: per-slot accepted counts (B,) — every engine slot
    keeps its own prefix.  Accepted rows scatter through the block table
    into the shared pools; rejected or inactive rows route out of bounds
    and drop.  Parked slots neither write nor advance.

    Quantized caches (``"k_scale" in unit``) quantize the pending rows
    per-row at commit time and scatter the int8 rows plus their fp32
    scales through the same index — dropped rows drop both halves, so a
    row's (q, scale) pair is always written atomically."""
    pos = cache["pos"]                                       # (B,)
    bt = cache["block_tables"]
    new_units = {}
    for name, c in cache["units"].items():
        pend = pending[name]
        quantized = "k_scale" in c
        nu, B, Q = pend["k"].shape[0], pend["k"].shape[1], pend["k"].shape[2]
        P, ps = c["k"].shape[1], c["k"].shape[2]
        i = jnp.arange(Q)[None, :]                           # (1, Q)
        posq = pos[:, None] + i                              # (B, Q)
        page = jnp.take_along_axis(bt, jnp.minimum(posq // ps,
                                                   bt.shape[1] - 1), axis=1)
        row = page * ps + posq % ps
        ok = (i <= n_accept[:, None]) & (active[:, None] > 0)
        rows = jnp.where(ok, row, P * ps).reshape(-1)        # OOB dropped

        def scatter(pool, vals, rows=rows, nu=nu, B=B, Q=Q, P=P, ps=ps):
            flat = pool.reshape(nu, P * ps, *pool.shape[3:])
            flat = flat.at[:, rows].set(
                vals.astype(flat.dtype).reshape(nu, B * Q, *vals.shape[3:]),
                mode="drop")
            return flat.reshape(pool.shape)

        new = {}
        for key in ("k", "v"):
            if quantized:
                qrows, srows = quant.quantize_int8_rows(pend[key])
                new[key] = scatter(c[key], qrows)
                new[key + "_scale"] = scatter(c[key + "_scale"], srows)
            else:
                new[key] = scatter(c[key], pend[key])
        new_units[name] = new
    adv = jnp.where(active > 0, n_accept + 1, 0)
    return {"pos": pos + adv, "block_tables": bt, "units": new_units}


def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(),
                *, active: jax.Array | None = None):
    """One decode step: tokens (B, 1) [or (B, 1, n_cb)] + cache -> logits,
    updated cache.

    Two cache layouts share this seam, discriminated by pytree structure
    (keys are static under jit): the classic ring buffer (scalar ``pos``,
    per-slot ring per layer) and the paged layout from ``init_paged_cache``
    (per-slot ``pos``/``block_tables``, shared page pools).  ``active``
    applies to the paged layout only: it gates which slots advance."""
    if "block_tables" in cache:
        return _paged_decode_step(params, cache, tokens, cfg, ctx, active)
    pos = cache["pos"]
    x = embed_tokens(params, tokens, cfg, ctx)
    emb0 = x
    shared = params.get("shared_attn")

    new_dense = []
    for blk, c in zip(params.get("dense_layers", []), cache.get("dense", [])):
        x, c = _block_decode(blk, x, pos, c, cfg, ctx, window=cfg.sliding_window)
        new_dense.append(c)

    def body(x, xs):
        unit, c_unit = xs
        u = unit_size(cfg)
        new_c = {}
        for i in range(u):
            sub, c = unit[f"sub{i}"], c_unit[f"sub{i}"]
            if cfg.uses_ssm:
                h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
                out, (conv, ssm_state) = ssm.mamba_decode(
                    sub, h, (c["conv"], c["ssm"]), cfg, constrain=ctx.constrain)
                x = x + out
                new_c[f"sub{i}"] = {"conv": conv, "ssm": ssm_state}
            else:
                window = cfg.window_for_layer(i)
                x, c2 = _block_decode(sub, x, pos, c, cfg, ctx, window=window)
                new_c[f"sub{i}"] = c2
        if shared is not None:
            h = jnp.concatenate([x, emb0], axis=-1) @ shared["w_in"].astype(x.dtype)
            sc = c_unit["__shared__"]
            out, sc2 = _block_decode(shared["block"], h, pos, sc, cfg, ctx,
                                     window=0)
            x = x + (out - h)
            new_c["__shared__"] = sc2
        return x, new_c

    units_cache = cache["units"]
    if shared is not None:
        units_cache = dict(units_cache)
        units_cache["__shared__"] = cache["shared"]
    x, new_units = jax.lax.scan(body, x, (params["layers"], units_cache))

    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)

    new_cache = {"pos": pos + 1, "units": {k: v for k, v in new_units.items()
                                           if k != "__shared__"}}
    if shared is not None:
        new_cache["shared"] = new_units["__shared__"]
    if new_dense:
        new_cache["dense"] = new_dense
    return logits, new_cache
