"""The LM trunk: init / train-forward / prefill / decode for every assigned
architecture family.

Layers are stacked into *pattern units* and iterated with ``lax.scan`` so the
HLO stays O(1) in depth (46-layer gemma2 compiles as fast as 2 layers):

  * dense / moe / ssm:  unit = 1 layer, scan over n_layers.
  * gemma2 (local/global alternation): unit = 2 layers (sub0 local window,
    sub1 global) — both sublayers are distinct programs in the scan body, so
    compiled FLOPs are honest (no lax.cond double-counting).
  * deepseek (first layer dense): layer 0 unrolled, units = remaining layers.
  * zamba2: unit = ``hybrid_attn_every`` mamba layers + ONE application of a
    *shared* attention block (single param copy, closed over by the scan
    body; Zamba2's core trick).

Each apply function takes a ``RunCtx`` carrying the mesh context, kernel
policy, activation-sharding ``constrain`` hook, and remat policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import quant
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import common, ssm
from repro.models.attention import ParamLeaf, pl_, split_leaves
from repro.models.config import ModelConfig
from repro.models.layers import NO_MESH, ParallelCtx, init_mlp, init_moe, \
    mlp_forward, moe_forward

VOCAB_PAD = 256


def padded_vocab(cfg: ModelConfig) -> int:
    v = cfg.vocab_size
    return (v + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


@dataclasses.dataclass(frozen=True)
class RunCtx:
    parallel: ParallelCtx = NO_MESH
    kernel_policy: ops.KernelPolicy = ops.DEFAULT_POLICY
    constrain: Callable[[jax.Array, tuple], jax.Array] | None = None
    remat: str = "none"                 # none | full | dots
    decode_cache_len: int = 0           # 0 -> cfg.max_seq_len


def unit_size(cfg: ModelConfig) -> int:
    if cfg.local_global:
        return 2
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        return cfg.hybrid_attn_every
    return 1


def n_units(cfg: ModelConfig) -> int:
    u = unit_size(cfg)
    body_layers = cfg.n_layers - cfg.first_dense_layers
    if body_layers % u:
        raise ValueError(f"{cfg.name}: {body_layers} layers not divisible by "
                         f"pattern unit {u}")
    return body_layers // u


# ==========================================================================
# init
# ==========================================================================
def _init_block(key, cfg: ModelConfig, *, moe: bool, d_ff: int | None = None):
    """One transformer sublayer: norm -> attn -> norm -> ffn."""
    k1, k2 = common.split_keys(key, 2)
    dt = cfg.param_dtype
    blk: dict[str, Any] = {
        "norm1": ParamLeaf(_norm_init(cfg), (None,)),
        "attn": (attn.init_mla(k1, cfg) if cfg.use_mla else attn.init_gqa(k1, cfg)),
        "norm2": ParamLeaf(_norm_init(cfg), (None,)),
        "ffn": (init_moe(k2, cfg) if moe else init_mlp(k2, cfg, d_ff)),
    }
    if cfg.post_norms:
        blk["post_attn_norm"] = ParamLeaf(_norm_init(cfg), (None,))
        blk["post_ffn_norm"] = ParamLeaf(_norm_init(cfg), (None,))
    return blk


def _norm_init(cfg: ModelConfig):
    # gemma stores (1 + w): init w at 0; others init scale at 1
    if cfg.post_norms:
        return common.zeros((cfg.d_model,), cfg.param_dtype)
    return common.ones((cfg.d_model,), cfg.param_dtype)


def _init_unit(key, cfg: ModelConfig):
    """One pattern unit (see module docstring)."""
    u = unit_size(cfg)
    keys = common.split_keys(key, u)
    unit: dict[str, Any] = {}
    for i in range(u):
        if cfg.uses_ssm:
            unit[f"sub{i}"] = ssm.init_mamba(keys[i], cfg)
        else:
            unit[f"sub{i}"] = _init_block(keys[i], cfg, moe=cfg.uses_moe)
    return unit


def _init_shared_attn(key, cfg: ModelConfig):
    """Zamba2's shared block: input = concat(hidden, embeddings) -> proj to
    d -> attn + MLP -> residual add into the trunk."""
    k0, k1 = common.split_keys(key, 2)
    d = cfg.d_model
    return {
        "w_in": pl_(k0, (2 * d, d), ("embed", "embed_out"), dtype=cfg.param_dtype),
        "block": _init_block(k1, cfg, moe=False),
    }


def init_lm(key, cfg: ModelConfig):
    """Returns (params, logical_axes) raw trees (ParamLeaf already split)."""
    keys = common.split_keys(key, 8)
    Vp = padded_vocab(cfg)
    d = cfg.d_model
    dt = cfg.param_dtype
    tree: dict[str, Any] = {}

    if cfg.n_codebooks:
        tree["embed"] = pl_(keys[0], (cfg.n_codebooks, Vp, d),
                            (None, "vocab", "embed"), std=0.02, dtype=dt)
        tree["lm_head"] = pl_(keys[1], (cfg.n_codebooks, d, Vp),
                              (None, "embed", "vocab"), std=0.02, dtype=dt)
    else:
        tree["embed"] = pl_(keys[0], (Vp, d), ("vocab", "embed"),
                            std=0.02, dtype=dt)
        if not cfg.tie_embeddings:
            tree["lm_head"] = pl_(keys[1], (d, Vp), ("embed", "vocab"),
                                  std=0.02, dtype=dt)

    if cfg.first_dense_layers:
        dense_keys = common.split_keys(keys[2], cfg.first_dense_layers)
        tree["dense_layers"] = [
            _init_block(dk, cfg, moe=False, d_ff=cfg.dense_d_ff or cfg.d_ff)
            for dk in dense_keys]

    nu = n_units(cfg)
    unit_keys = jax.random.split(keys[3], nu)
    stacked = jax.vmap(functools.partial(_init_unit, cfg=cfg))(unit_keys)
    # prepend the stacked "layers" axis to every leaf's logical axes
    is_leaf = lambda x: isinstance(x, ParamLeaf)
    stacked = jax.tree.map(
        lambda l: ParamLeaf(l.array, ("layers",) + tuple(l.axes)),
        stacked, is_leaf=is_leaf)
    tree["layers"] = stacked

    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        tree["shared_attn"] = _init_shared_attn(keys[4], cfg)

    tree["final_norm"] = ParamLeaf(_norm_init(cfg), (None,))
    return split_leaves(tree)


# ==========================================================================
# sublayer application
# ==========================================================================
def _norm(x, scale, cfg: ModelConfig):
    return common.rmsnorm(x, scale, cfg.norm_eps, gemma_style=cfg.post_norms)


def _apply_block(blk, x, positions, cfg: ModelConfig, ctx: RunCtx, *,
                 window: int, aux: jax.Array):
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla:
        a = attn.mla_forward(blk["attn"], h, positions, cfg,
                             policy=ctx.kernel_policy, constrain=ctx.constrain)
    else:
        a = attn.gqa_forward(blk["attn"], h, positions, cfg, window=window,
                             policy=ctx.kernel_policy, constrain=ctx.constrain)
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, aux_l = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                               constrain=ctx.constrain)
        aux = aux + aux_l
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, aux


def _apply_shared_attn(shared, x, emb0, positions, cfg: ModelConfig,
                       ctx: RunCtx, aux):
    h = jnp.concatenate([x, emb0], axis=-1) @ shared["w_in"].astype(x.dtype)
    out, aux = _apply_block(shared["block"], h, positions, cfg, ctx,
                            window=0, aux=aux)
    return x + (out - h), aux    # residual delta of the shared block


def _apply_unit(unit, x, emb0, positions, cfg: ModelConfig, ctx: RunCtx,
                shared, aux):
    u = unit_size(cfg)
    for i in range(u):
        sub = unit[f"sub{i}"]
        if cfg.uses_ssm:
            h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
            x = x + ssm.mamba_forward(sub, h, cfg, policy=ctx.kernel_policy,
                                      constrain=ctx.constrain)
        else:
            window = cfg.window_for_layer(i)
            x, aux = _apply_block(sub, x, positions, cfg, ctx,
                                  window=window, aux=aux)
    if shared is not None:
        x, aux = _apply_shared_attn(shared, x, emb0, positions, cfg, ctx, aux)
    return x, aux


# ==========================================================================
# embedding / head
# ==========================================================================
def embed_tokens(params, tokens, cfg: ModelConfig, ctx: RunCtx):
    adt = common.dt(cfg.dtype)
    if cfg.n_codebooks:
        # tokens: (B, S, n_cb) — sum of per-codebook embeddings
        embs = params["embed"].astype(adt)          # (n_cb, Vp, d)
        x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), adt)
        for c in range(cfg.n_codebooks):
            x = x + embs[c][tokens[..., c]]
    else:
        x = params["embed"].astype(adt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, adt)
    if ctx.constrain is not None:
        x = ctx.constrain(x, ("batch", None, "embed_act"))
    return x


def lm_logits(params, x, cfg: ModelConfig, ctx: RunCtx):
    adt = x.dtype
    Vp = padded_vocab(cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"].astype(adt))
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].astype(adt).T
    else:
        logits = x @ params["lm_head"].astype(adt)
    if cfg.final_logit_softcap > 0.0:
        logits = common.softcap(logits, cfg.final_logit_softcap)
    # mask the padded vocab tail
    if Vp != cfg.vocab_size:
        mask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    if ctx.constrain is not None:
        spec = ("batch", None, None, "vocab") if cfg.n_codebooks \
            else ("batch", None, "vocab")
        logits = ctx.constrain(logits, spec)
    return logits


# ==========================================================================
# full forward (training)
# ==========================================================================
def forward(params, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(), *,
            extra_embeds: jax.Array | None = None):
    """Token ids -> logits.  ``extra_embeds`` (B, n_img, d) is the LLaVA
    vision prefix (precomputed patch embeddings; frontend is a stub)."""
    x = embed_tokens(params, tokens, cfg, ctx)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    emb0 = x
    aux0 = jnp.zeros((), jnp.float32)

    for dense_blk in params.get("dense_layers", []):
        x, aux0 = _apply_block(dense_blk, x, positions, cfg, ctx,
                               window=cfg.sliding_window, aux=aux0)

    shared = params.get("shared_attn")

    def body(carry, unit):
        x, aux = carry
        x, aux = _apply_unit(unit, x, emb0, positions, cfg, ctx, shared, aux)
        return (x, aux), None

    if ctx.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif ctx.remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)
    return logits, aux


def lm_loss(params, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(), *,
            extra_embeds: jax.Array | None = None):
    """Next-token CE (+ MoE aux).  For multi-codebook audio, the loss is the
    mean CE over codebooks; for VLM, image-prefix positions carry no loss."""
    if cfg.n_codebooks:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        logits, aux = forward(params, inputs, cfg, ctx)
        losses = [common.cross_entropy(logits[:, :, c], labels[..., c])
                  for c in range(cfg.n_codebooks)]
        return sum(losses) / cfg.n_codebooks + aux
    inputs, labels, mask = common.shift_labels(tokens)
    logits, aux = forward(params, inputs, cfg, ctx, extra_embeds=extra_embeds)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    return common.cross_entropy(logits, labels, mask) + aux


def lm_loss_pre_shifted(params, inputs, targets, cfg: ModelConfig,
                        ctx: RunCtx = RunCtx(), *,
                        extra_embeds: jax.Array | None = None):
    """CE with a pre-shifted (inputs, targets) pair — the production data
    pipeline emits these so the step sees clean power-of-two seq lengths."""
    logits, aux = forward(params, inputs, cfg, ctx, extra_embeds=extra_embeds)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    if cfg.n_codebooks:
        losses = [common.cross_entropy(logits[:, :, c], targets[..., c])
                  for c in range(cfg.n_codebooks)]
        return sum(losses) / cfg.n_codebooks + aux
    return common.cross_entropy(logits, targets) + aux


# ==========================================================================
# prefill / decode
# ==========================================================================
def _cache_len(cfg: ModelConfig, ctx: RunCtx, seq_len: int, window: int) -> int:
    cap = ctx.decode_cache_len or max(cfg.max_seq_len, seq_len)
    if window > 0:
        cap = min(cap, window)
    return cap


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: str = "bfloat16"):
    """Zero-filled decode cache pytree (+ its logical sharding axes)."""
    adt = common.dt(dtype)
    hd = cfg.resolved_head_dim
    nu, u = n_units(cfg), unit_size(cfg)

    def attn_cache(cap):
        if cfg.use_mla:
            return {"lat": jnp.zeros(
                (nu, batch, cap, cfg.kv_lora_rank + cfg.rope_head_dim), adt)}
        hkv = cfg.padded_kv_heads
        return {"k": jnp.zeros((nu, batch, cap, hkv, hd), adt),
                "v": jnp.zeros((nu, batch, cap, hkv, hd), adt)}

    def mamba_cache():
        cd = ssm.conv_dim(cfg)
        H, P_, N = cfg.resolved_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        return {"conv": jnp.zeros((nu, batch, cfg.conv_width - 1, cd), adt),
                "ssm": jnp.zeros((nu, batch, H, P_, N), jnp.float32)}

    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    units: dict[str, Any] = {}
    for i in range(u):
        if cfg.uses_ssm:
            units[f"sub{i}"] = mamba_cache()
        else:
            w = cfg.window_for_layer(i)
            cap = min(max_len, w) if w > 0 else max_len
            # MLA caches have no per-head dim; GQA caches are per-kv-head
            c = attn_cache(cap)
            units[f"sub{i}"] = c
    cache["units"] = units
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        cap = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        cache["shared"] = {
            "k": jnp.zeros((nu, batch, cap, cfg.padded_kv_heads, hd), adt),
            "v": jnp.zeros((nu, batch, cap, cfg.padded_kv_heads, hd), adt)}
    if cfg.first_dense_layers:
        cap = max_len
        dc = []
        for _ in range(cfg.first_dense_layers):
            if cfg.use_mla:
                dc.append({"lat": jnp.zeros(
                    (batch, cap, cfg.kv_lora_rank + cfg.rope_head_dim), adt)})
            else:
                hkv = cfg.padded_kv_heads
                dc.append({"k": jnp.zeros((batch, cap, hkv, hd), adt),
                           "v": jnp.zeros((batch, cap, hkv, hd), adt)})
        cache["dense"] = dc
    return cache


def paged_cache_blockers(cfg: ModelConfig) -> tuple[str, ...]:
    """Named config features that keep a model family OFF the paged engine.

    Empty for every family in the zoo: dense/MoE/codebook GQA ride the
    shared page pools; MLA layers pool ONE compressed latent row per token;
    sliding-window layers hold O(window) private ring pages behind a static
    identity table; SSM layers park O(1) recurrent state in per-slot state
    slots of the same cache pytree; deepseek's first dense layers get their
    own stacked pool on the same page-id space.  The tuple form is the
    contract: capability gates report the SPECIFIC blocking feature by
    name, never a blanket boolean — an empty tuple means "serve it"."""
    del cfg
    return ()


def supports_paged_cache(cfg: ModelConfig) -> bool:
    return not paged_cache_blockers(cfg)


def int8_paged_blockers(cfg: ModelConfig) -> tuple[str, ...]:
    """Features blocking the int8 paged storage mode: the per-row scale
    leaves pair with full-length k/v page pools, which SSM state slots,
    latent (MLA) pools, private windowed rings, and the hybrid shared
    buffer do not carry."""
    checks = (("uses_ssm", cfg.uses_ssm), ("use_mla", cfg.use_mla),
              ("sliding_window", bool(cfg.sliding_window)),
              ("local_global", cfg.local_global),
              ("first_dense_layers", bool(cfg.first_dense_layers)),
              ("hybrid_attn_every",
               cfg.family == "hybrid" and bool(cfg.hybrid_attn_every)))
    return tuple(name for name, on in checks if on)


def init_paged_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                     page_size: int, max_blocks: int,
                     dtype: str = "bfloat16"):
    """Zero-filled paged decode cache: per-unit page *pools* shared by every
    slot, one block table and one position counter per slot.

    Per-family layout — every group keeps page axis 1 so the engine's page
    accounting / snapshot / host-tier seams iterate them uniformly:

      * full-attention GQA unit: k/v pools (n_units, n_pages, page_size,
        Hkv, hd) addressed through ``block_tables`` (positions
        [j*ps, (j+1)*ps) live on physical page ``block_tables[s, j]``; the
        engine parks free slots on a reserved per-slot scratch page so
        decode needs no validity branch).
      * MLA unit: ONE latent pool (n_units, n_pages, page_size, R) with
        R = kv_lora_rank + rope_head_dim — a single row per token shared
        by every head (~5x fewer KV bytes than per-head k/v), on the same
        block tables.
      * sliding-window unit: a PRIVATE ring of ``nbw = ceil(min(max_len,
        window)/ps)`` pages per slot, pool (n_units, n_slots*nbw, ps, Hkv,
        hd).  The "page table" is the static identity ``slot*nbw + j`` and
        logical blocks wrap at ``window/page_size`` — O(window) bytes per
        slot no matter how deep the stream runs, no host page management.
      * SSM unit: per-slot O(1) state slots {"conv": (n_units, n_slots,
        conv_width-1, cd), "ssm": (n_units, n_slots, H, P, N) fp32} — state
        rides the cache pytree, so snapshot/restore, preemption-fold and
        chaos drills cover recurrent layers unchanged.
      * hybrid shared block: per-slot linear buffer ``cache["shared"]``
        (n_units, n_slots, max_len, Hkv, hd), decoded through the paged
        sweep behind a static identity table.
      * first dense layers: ``cache["dense"]`` — a stacked group
        (n_dense, n_pages, page_size, ...) sharing the main page-id space.

    ``pos`` is per-slot — the batch is ragged by construction.

    ``dtype="int8"`` selects the quantized storage mode: int8 pools plus
    per-ROW-per-kv-head fp32 scale leaves ``k_scale``/``v_scale`` of shape
    (n_units, n_pages, page_size, Hkv, 1).  Rows are quantized at write
    time (decode scatter / speculative commit) and dequantized inside the
    attention sweep; a row, once written, never rescales, so page-level
    sharing and snapshots stay bit-stable.  The cache *structure* carries
    the mode — downstream seams discriminate on ``"k_scale" in unit``,
    which is static under jit."""
    blockers = paged_cache_blockers(cfg)
    if blockers:
        raise ValueError(f"{cfg.name}: paged KV cache blocked by "
                         f"{blockers[0]}")
    quantized = dtype == "int8"
    if quantized:
        i8_block = int8_paged_blockers(cfg)
        if i8_block:
            raise ValueError(f"{cfg.name}: int8 paged cache blocked by "
                             f"{i8_block[0]}")
    adt = jnp.int8 if quantized else common.dt(dtype)
    hd = cfg.resolved_head_dim
    nu, u = n_units(cfg), unit_size(cfg)
    hkv = cfg.padded_kv_heads
    R = cfg.kv_lora_rank + cfg.rope_head_dim
    max_len = max_blocks * page_size

    units: dict[str, Any] = {}
    for i in range(u):
        if cfg.uses_ssm:
            cd = ssm.conv_dim(cfg)
            H, P_, N = cfg.resolved_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            units[f"sub{i}"] = {
                "conv": jnp.zeros((nu, n_slots, cfg.conv_width - 1, cd), adt),
                "ssm": jnp.zeros((nu, n_slots, H, P_, N), jnp.float32)}
        elif cfg.use_mla:
            units[f"sub{i}"] = {
                "lat": jnp.zeros((nu, n_pages, page_size, R), adt)}
        else:
            w = cfg.window_for_layer(i)
            if w > 0:
                nbw = -(-min(max_len, w) // page_size)
                units[f"sub{i}"] = {
                    "k": jnp.zeros((nu, n_slots * nbw, page_size, hkv, hd),
                                   adt),
                    "v": jnp.zeros((nu, n_slots * nbw, page_size, hkv, hd),
                                   adt)}
            else:
                sub = {"k": jnp.zeros((nu, n_pages, page_size, hkv, hd), adt),
                       "v": jnp.zeros((nu, n_pages, page_size, hkv, hd), adt)}
                if quantized:
                    sub["k_scale"] = jnp.zeros(
                        (nu, n_pages, page_size, hkv, 1), jnp.float32)
                    sub["v_scale"] = jnp.zeros(
                        (nu, n_pages, page_size, hkv, 1), jnp.float32)
                units[f"sub{i}"] = sub
    cache = {"pos": jnp.zeros((n_slots,), jnp.int32),
             "block_tables": jnp.zeros((n_slots, max_blocks), jnp.int32),
             "units": units}
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        cache["shared"] = {
            "k": jnp.zeros((nu, n_slots, max_len, hkv, hd), adt),
            "v": jnp.zeros((nu, n_slots, max_len, hkv, hd), adt)}
    if cfg.first_dense_layers:
        nd = cfg.first_dense_layers
        if cfg.use_mla:
            cache["dense"] = {
                "lat": jnp.zeros((nd, n_pages, page_size, R), adt)}
        else:
            cache["dense"] = {
                "k": jnp.zeros((nd, n_pages, page_size, hkv, hd), adt),
                "v": jnp.zeros((nd, n_pages, page_size, hkv, hd), adt)}
    return cache


def _block_prefill(blk, x, positions, cfg: ModelConfig, ctx: RunCtx, *,
                   window: int, cache_len: int, aux):
    """_apply_block that also emits this layer's decode cache."""
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla:
        a, lat = attn.mla_prefill(blk["attn"], h, positions, cfg,
                                  cache_len=cache_len,
                                  policy=ctx.kernel_policy,
                                  constrain=ctx.constrain)
        c = {"lat": lat}
    else:
        a, (k, v) = attn.gqa_prefill(blk["attn"], h, positions, cfg,
                                     window=window, cache_len=cache_len,
                                     policy=ctx.kernel_policy,
                                     constrain=ctx.constrain)
        c = {"k": k, "v": v}
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, aux_l = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                               constrain=ctx.constrain)
        aux = aux + aux_l
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, c, aux


def prefill(params, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(), *,
            max_len: int = 0, extra_embeds: jax.Array | None = None,
            full_cache: bool = False):
    """Process the full prompt and build the decode cache.

    Returns (logits, cache) — logits for every prompt position (the serving
    layer samples from the last one); cache['pos'] = prompt length.

    ``full_cache=True`` keeps sliding-window layers' caches LINEAR at
    capacity ``max_len`` instead of wrapping them into an O(window) ring:
    position ``p``'s row sits at index ``p``.  The serving engine needs
    this for page inject — prompts pad up to a power-of-2 bucket, and in
    the ring layout the pad rows written past the prompt would overwrite
    the real window tail before the engine can scatter it into the slot's
    private ring pages.  Attention masking is unchanged (the window still
    clips scores); only the emitted cache layout differs.
    """
    x = embed_tokens(params, tokens, cfg, ctx)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    max_len = max_len or max(cfg.max_seq_len, S)
    positions = jnp.arange(S)[None, :]
    emb0 = x
    aux = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")

    dense_cache = []
    for blk in params.get("dense_layers", []):
        cap = min(max_len, cfg.sliding_window) \
            if (cfg.sliding_window and not full_cache) else max_len
        x, c, aux = _block_prefill(blk, x, positions, cfg, ctx,
                                   window=cfg.sliding_window,
                                   cache_len=cap, aux=aux)
        dense_cache.append(c)

    def body(carry, unit):
        x, aux = carry
        u = unit_size(cfg)
        unit_cache = {}
        for i in range(u):
            sub = unit[f"sub{i}"]
            if cfg.uses_ssm:
                h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
                out, (conv, ssm_state) = ssm.mamba_forward(
                    sub, h, cfg, policy=ctx.kernel_policy,
                    constrain=ctx.constrain, return_state=True)
                x = x + out
                unit_cache[f"sub{i}"] = {"conv": conv, "ssm": ssm_state}
            else:
                w = cfg.window_for_layer(i)
                cap = min(max_len, w) if (w > 0 and not full_cache) \
                    else max_len
                x, c, aux = _block_prefill(sub, x, positions, cfg, ctx,
                                           window=w, cache_len=cap, aux=aux)
                unit_cache[f"sub{i}"] = c
        if shared is not None:
            h = jnp.concatenate([x, emb0], axis=-1) @ shared["w_in"].astype(x.dtype)
            out, c, aux = _block_prefill(shared["block"], h, positions, cfg,
                                         ctx, window=0, cache_len=max_len,
                                         aux=aux)
            x = x + (out - h)
            unit_cache["__shared__"] = c
        return (x, aux), unit_cache

    (x, aux), unit_caches = jax.lax.scan(body, (x, aux), params["layers"])
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)

    cache = {"pos": jnp.asarray(S, jnp.int32),
             "units": {k: v for k, v in unit_caches.items()
                       if k != "__shared__"}}
    if shared is not None:
        cache["shared"] = unit_caches["__shared__"]
    if dense_cache:
        cache["dense"] = dense_cache
    return logits, cache


def _block_decode(blk, x, pos, c, cfg: ModelConfig, ctx: RunCtx, *,
                  window: int, block_tables: jax.Array | None = None):
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla and block_tables is not None:
        a, lat = attn.mla_decode_paged(blk["attn"], h, pos, c["lat"],
                                       block_tables, cfg,
                                       policy=ctx.kernel_policy,
                                       constrain=ctx.constrain)
        c = {"lat": lat}
    elif cfg.use_mla:
        a, lat = attn.mla_decode(blk["attn"], h, pos, c["lat"], cfg,
                                 policy=ctx.kernel_policy,
                                 constrain=ctx.constrain)
        c = {"lat": lat}
    elif block_tables is not None and window > 0:
        # sliding-window layer on the paged engine: the pool is a batch of
        # PRIVATE per-slot rings ((n_slots*nbw, ps, Hkv, *) -> (B, Cw, ...))
        # behind a static identity table — ragged pos masks per row
        B = pos.shape[0]
        kp, vp = c["k"], c["v"]
        nbw, ps = kp.shape[0] // B, kp.shape[1]
        ring = lambda p: p.reshape(B, nbw * ps, *p.shape[2:])
        a, (k, v) = attn.gqa_decode_ragged(blk["attn"], h, pos,
                                           (ring(kp), ring(vp)), cfg,
                                           window=window,
                                           policy=ctx.kernel_policy,
                                           constrain=ctx.constrain)
        c = {"k": k.reshape(kp.shape), "v": v.reshape(vp.shape)}
    elif block_tables is not None:
        if "k_scale" in c:       # int8 pools: thread the scale leaves
            kv_in = (c["k"], c["v"], c["k_scale"], c["v_scale"])
            a, kv_out = attn.gqa_decode_paged(blk["attn"], h, pos, kv_in,
                                              block_tables, cfg,
                                              window=window,
                                              policy=ctx.kernel_policy,
                                              constrain=ctx.constrain)
            c = dict(zip(("k", "v", "k_scale", "v_scale"), kv_out))
        else:
            a, (k, v) = attn.gqa_decode_paged(blk["attn"], h, pos,
                                              (c["k"], c["v"]), block_tables,
                                              cfg, window=window,
                                              policy=ctx.kernel_policy,
                                              constrain=ctx.constrain)
            c = {"k": k, "v": v}
    else:
        a, (k, v) = attn.gqa_decode(blk["attn"], h, pos, (c["k"], c["v"]),
                                    cfg, window=window,
                                    policy=ctx.kernel_policy,
                                    constrain=ctx.constrain)
        c = {"k": k, "v": v}
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, _ = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                           constrain=ctx.constrain)
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    return x + f, c


def _paged_decode_step(params, cache, tokens, cfg: ModelConfig, ctx: RunCtx,
                       active: jax.Array | None):
    """decode_step over the paged cache layout: per-slot positions, block
    tables, shared page pools.  ``active`` (B,) gates the position advance —
    parked slots keep rewriting row ``pos[b]`` of their scratch page (or
    their private ring / state slot) and their sampled tokens are discarded
    by the engine, so one executable serves every occupancy pattern.

    Routing mirrors the ring ``decode_step`` sub for sub: MLA units sweep
    the latent pool, sliding-window units their private rings, SSM units
    advance per-slot recurrent state, first dense layers and the hybrid
    shared block run before/inside the scan — the full model zoo behind
    ONE seam."""
    pos = cache["pos"]                                     # (B,)
    bt = cache["block_tables"]
    x = embed_tokens(params, tokens, cfg, ctx)
    emb0 = x
    shared = params.get("shared_attn")

    new_dense = None
    if cfg.first_dense_layers:
        new_layers = []
        for j, blk in enumerate(params["dense_layers"]):
            c = jax.tree.map(lambda p: p[j], cache["dense"])
            x, c2 = _block_decode(blk, x, pos, c, cfg, ctx,
                                  window=cfg.sliding_window, block_tables=bt)
            new_layers.append(c2)
        new_dense = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)

    def body(x, xs):
        unit, c_unit = xs
        new_c = {}
        for i in range(unit_size(cfg)):
            sub, c = unit[f"sub{i}"], c_unit[f"sub{i}"]
            if cfg.uses_ssm:
                h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
                out, (conv, ssm_state) = ssm.mamba_decode(
                    sub, h, (c["conv"], c["ssm"]), cfg,
                    constrain=ctx.constrain)
                x = x + out
                # state pools keep their storage dtype (mamba_decode
                # computes the conv tail in activation dtype): the fused
                # serving loop carries the cache through lax.scan, which
                # needs a dtype-stable carry
                new_c[f"sub{i}"] = {"conv": conv.astype(c["conv"].dtype),
                                    "ssm": ssm_state.astype(c["ssm"].dtype)}
            else:
                w = cfg.window_for_layer(i)
                x, c2 = _block_decode(sub, x, pos, c, cfg, ctx, window=w,
                                      block_tables=bt)
                new_c[f"sub{i}"] = c2
        if shared is not None:
            # per-slot linear buffer behind a static identity table: slot
            # b's block j IS physical page b*nbs + j of the reshaped pool
            h = jnp.concatenate([x, emb0], axis=-1) \
                @ shared["w_in"].astype(x.dtype)
            sc = c_unit["__shared__"]
            B, Cs = sc["k"].shape[0], sc["k"].shape[1]
            nbs = bt.shape[1]
            ps = Cs // nbs
            bt_id = jnp.arange(B * nbs, dtype=jnp.int32).reshape(B, nbs)
            pool = lambda p: p.reshape(B * nbs, ps, *p.shape[2:])
            out, sc2 = _block_decode(shared["block"], h, pos,
                                     {"k": pool(sc["k"]), "v": pool(sc["v"])},
                                     cfg, ctx, window=0, block_tables=bt_id)
            x = x + (out - h)
            new_c["__shared__"] = {"k": sc2["k"].reshape(sc["k"].shape),
                                   "v": sc2["v"].reshape(sc["v"].shape)}
        return x, new_c

    units_cache = cache["units"]
    if shared is not None:
        units_cache = dict(units_cache)
        units_cache["__shared__"] = cache["shared"]
    x, new_units = jax.lax.scan(body, x, (params["layers"], units_cache))
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)
    adv = jnp.ones_like(pos) if active is None \
        else jnp.asarray(active, jnp.int32)
    new_cache = {"pos": pos + adv, "block_tables": bt,
                 "units": {k: v for k, v in new_units.items()
                           if k != "__shared__"}}
    if shared is not None:
        new_cache["shared"] = new_units["__shared__"]
    if new_dense is not None:
        new_cache["dense"] = new_dense
    return logits, new_cache


def speculative_blockers(cfg: ModelConfig) -> tuple[str, ...]:
    """Named features blocking speculative verify/commit.  SSM recurrence
    would need per-step state snapshots to roll back, MLA decode runs the
    absorbed custom path (drafting against it is a follow-on), multi-
    codebook drafts would have to match on every codebook, and the hybrid
    shared block carries its own cache."""
    checks = (("uses_ssm", cfg.uses_ssm), ("use_mla", cfg.use_mla),
              ("n_codebooks", bool(cfg.n_codebooks)),
              ("first_dense_layers", bool(cfg.first_dense_layers)),
              ("hybrid_attn_every",
               cfg.family == "hybrid" and bool(cfg.hybrid_attn_every)))
    return tuple(name for name, on in checks if on)


def supports_speculative(cfg: ModelConfig) -> bool:
    return not speculative_blockers(cfg)


def chunked_prefill_blockers(cfg: ModelConfig) -> tuple[str, ...]:
    """Named features blocking the paged multi-query sweep behind chunked
    prefill / prefix-cache joins (``prefill_suffix``): SSM and the hybrid
    shared block are recurrent or privately cached (no shared full-length
    pool to sweep a suffix chunk against), windowed layers keep O(window)
    ring pages, codebook models feed (B, Q, n_cb) tokens.  MLA and first
    dense layers ARE covered — the latent pool in absorbed form is a
    single-kv-head GQA pool, which is what lets deepseek ride the prefix
    cache."""
    checks = (("uses_ssm", cfg.uses_ssm),
              ("n_codebooks", bool(cfg.n_codebooks)),
              ("hybrid_attn_every",
               cfg.family == "hybrid" and bool(cfg.hybrid_attn_every)),
              ("sliding_window", bool(cfg.sliding_window)),
              ("local_global", cfg.local_global))
    return tuple(name for name, on in checks if on)


def _block_verify(blk, x, pos, c, cfg: ModelConfig, ctx: RunCtx, *,
                  window: int, block_tables: jax.Array | None = None):
    """_block_decode's speculative sibling: scores the whole fed block in
    one cache sweep and returns this layer's *pending* k/v rows instead of
    writing the cache."""
    h = _norm(x, blk["norm1"], cfg)
    if cfg.use_mla:
        # paged-only (the ring gate names use_mla): the latent pool in
        # absorbed form is a single-kv-head GQA pool — generic sweep
        a, lat_new = attn.mla_verify_paged(blk["attn"], h, pos, c["lat"],
                                           block_tables, cfg,
                                           policy=ctx.kernel_policy,
                                           constrain=ctx.constrain)
        kv_new = None
    elif block_tables is not None:
        kv_in = ((c["k"], c["v"], c["k_scale"], c["v_scale"])
                 if "k_scale" in c else (c["k"], c["v"]))
        a, kv_new = attn.gqa_verify_paged(blk["attn"], h, pos, kv_in,
                                          block_tables,
                                          cfg, window=window,
                                          policy=ctx.kernel_policy,
                                          constrain=ctx.constrain)
    else:
        a, kv_new = attn.gqa_verify(blk["attn"], h, pos, (c["k"], c["v"]),
                                    cfg, window=window,
                                    policy=ctx.kernel_policy,
                                    constrain=ctx.constrain)
    if cfg.post_norms:
        a = _norm(a, blk["post_attn_norm"], cfg)
    x = x + a
    h = _norm(x, blk["norm2"], cfg)
    if "router" in blk["ffn"]:
        f, _ = moe_forward(blk["ffn"], h, cfg, ctx.parallel,
                           constrain=ctx.constrain)
    else:
        f = mlp_forward(blk["ffn"], h, cfg, constrain=ctx.constrain)
    if cfg.post_norms:
        f = _norm(f, blk["post_ffn_norm"], cfg)
    pend = {"lat": lat_new} if kv_new is None \
        else {"k": kv_new[0], "v": kv_new[1]}
    return x + f, pend


def verify_step(params, cache, tokens, cfg: ModelConfig,
                ctx: RunCtx = RunCtx()):
    """Score ``Q = K+1`` speculative tokens in ONE cache sweep.

    tokens: (B, Q) — the fed block [t_last, d_1..d_K] at positions
    ``pos .. pos+Q-1``.  Returns (logits (B, Q, V), pending) where
    ``pending`` mirrors ``cache['units']`` with per-layer candidate k/v
    rows of shape (n_units, B, Q, Hkv, hd) — NOTHING is committed past the
    accepted prefix until :func:`commit_spec` / :func:`commit_spec_paged`
    scatters rows ``0..n_accept`` and advances ``pos``.  Both cache
    layouts share this seam, discriminated by pytree structure exactly
    like ``decode_step``.

    MLA units pend one latent row per token ({"lat": (n_units, B, Q, R)},
    paged only); first dense layers pend under ``pending["__dense__"]``
    (stacked over layers) — absent for configs without them, so the
    established pending pytree is unchanged for the GQA families.  The
    gate is per-feature: ring sweeps require ``speculative_blockers``
    empty, paged sweeps ``chunked_prefill_blockers`` empty (the looser
    contract both the spec engine and prefix-cache joins build on)."""
    paged = "block_tables" in cache
    blockers = chunked_prefill_blockers(cfg) if paged \
        else speculative_blockers(cfg)
    if blockers:
        kind = "paged verify sweep" if paged else "speculative decode"
        raise ValueError(f"{cfg.name}: {kind} blocked by {blockers[0]}")
    pos = cache["pos"]                  # () ring | (B,) paged
    bt = cache.get("block_tables")
    x = embed_tokens(params, tokens, cfg, ctx)

    pend_dense = None
    if cfg.first_dense_layers:          # paged-only: the ring gate names it
        layer_pend = []
        for j, blk in enumerate(params["dense_layers"]):
            c = jax.tree.map(lambda p: p[j], cache["dense"])
            x, p = _block_verify(blk, x, pos, c, cfg, ctx,
                                 window=cfg.sliding_window, block_tables=bt)
            layer_pend.append(p)
        pend_dense = jax.tree.map(lambda *xs: jnp.stack(xs), *layer_pend)

    def body(x, xs):
        unit, c_unit = xs
        pend = {}
        for i in range(unit_size(cfg)):
            sub, c = unit[f"sub{i}"], c_unit[f"sub{i}"]
            window = 0 if paged else cfg.window_for_layer(i)
            x, p = _block_verify(sub, x, pos, c, cfg, ctx, window=window,
                                 block_tables=bt)
            pend[f"sub{i}"] = p
        return x, pend

    x, pending = jax.lax.scan(body, x, (params["layers"], cache["units"]))
    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)
    if pend_dense is not None:
        pending = dict(pending)
        pending["__dense__"] = pend_dense
    return logits, pending


def commit_spec(cache, pending, n_accept, cfg: ModelConfig):
    """Commit the accepted prefix of a verify step into the ring cache.

    ``pending`` holds rows for the fed block [t_last, d_1..d_K]; rows
    ``0..n_accept`` (t_last plus the accepted drafts) scatter into slots
    ``(pos + i) % C`` and ``pos`` advances by ``n_accept + 1``.  Rejected
    rows route to an out-of-bounds slot and are dropped — the ring's
    history is never touched past the accepted prefix, so there is nothing
    to roll back.  ``n_accept`` is a traced scalar: ONE executable serves
    every acceptance pattern inside the fused scan."""
    pos = cache["pos"]
    new_units = {}
    for name, c in cache["units"].items():
        pend = pending[name]
        Q = pend["k"].shape[2]
        C = c["k"].shape[2]
        i = jnp.arange(Q)
        slots = jnp.where(i <= n_accept, (pos + i) % C, C)   # C is OOB
        new_units[name] = {
            key: c[key].at[:, :, slots].set(
                pend[key].astype(c[key].dtype), mode="drop")
            for key in ("k", "v")}
    return {"pos": pos + n_accept + 1, "units": new_units}


def prefill_suffix(params, cache, tokens, n_commit, cfg: ModelConfig,
                   ctx: RunCtx = RunCtx()):
    """Chunked paged prefill: score a block of *prompt suffix* tokens
    against a slot's already-cached prefix and commit their k/v.

    This is the prefix-sharing engine's join path: when
    ``PagedKVCache.admit_with_prefix`` maps a cached prefix of length
    ``m``, only ``tokens[m:]`` need compute — and scoring a suffix chunk
    at positions ``pos .. pos+Q-1`` against pages committed through
    ``pos-1`` is *exactly* the speculative verify sweep with
    ``q_len = chunk`` (``ops.paged_verify_attention`` — no new kernel).
    The commit is the speculative commit with every real row accepted:
    ``n_commit`` (B,) counts each slot's real (non-pad) rows this chunk;
    rows ``0..n_commit-1`` scatter through the block table, ``pos``
    advances by ``n_commit``, and slots with ``n_commit == 0`` neither
    write nor advance — so one fixed-shape executable serves every join
    against the live engine cache without touching the other slots.

    Returns ``(logits, cache)``: row ``n_commit[b] - 1`` of slot b's
    logits scores the token after its last real suffix token (the
    engine's first-token sample on a full-suffix join)."""
    logits, pending = verify_step(params, cache, tokens, cfg, ctx)
    active = (n_commit > 0).astype(jnp.int32)
    new_cache = commit_spec_paged(cache, pending, n_commit - 1, active, cfg)
    return logits, new_cache


def commit_spec_paged(cache, pending, n_accept, active, cfg: ModelConfig):
    """Paged commit: per-slot accepted counts (B,) — every engine slot
    keeps its own prefix.  Accepted rows scatter through the block table
    into the shared pools; rejected or inactive rows route out of bounds
    and drop.  Parked slots neither write nor advance.

    Quantized caches (``"k_scale" in unit``) quantize the pending rows
    per-row at commit time and scatter the int8 rows plus their fp32
    scales through the same index — dropped rows drop both halves, so a
    row's (q, scale) pair is always written atomically.

    MLA units commit their single pending latent row per token through the
    identical scatter (key "lat", pool (n_units, P, ps, R)); a pending
    ``"__dense__"`` group commits into ``cache["dense"]`` the same way —
    dense layers share the main page-id space, so the SAME block-table
    rows address them."""
    pos = cache["pos"]                                       # (B,)
    bt = cache["block_tables"]

    def commit_group(c, pend):
        quantized = "k_scale" in c
        keys = [k for k in ("k", "v", "lat") if k in c]
        ng, P, ps = c[keys[0]].shape[0], c[keys[0]].shape[1], \
            c[keys[0]].shape[2]
        B, Q = pend[keys[0]].shape[1], pend[keys[0]].shape[2]
        i = jnp.arange(Q)[None, :]                           # (1, Q)
        posq = pos[:, None] + i                              # (B, Q)
        page = jnp.take_along_axis(bt, jnp.minimum(posq // ps,
                                                   bt.shape[1] - 1), axis=1)
        row = page * ps + posq % ps
        ok = (i <= n_accept[:, None]) & (active[:, None] > 0)
        rows = jnp.where(ok, row, P * ps).reshape(-1)        # OOB dropped

        def scatter(pool, vals):
            flat = pool.reshape(ng, P * ps, *pool.shape[3:])
            flat = flat.at[:, rows].set(
                vals.astype(flat.dtype).reshape(ng, B * Q, *vals.shape[3:]),
                mode="drop")
            return flat.reshape(pool.shape)

        new = {}
        for key in keys:
            if quantized and key in ("k", "v"):
                qrows, srows = quant.quantize_int8_rows(pend[key])
                new[key] = scatter(c[key], qrows)
                new[key + "_scale"] = scatter(c[key + "_scale"], srows)
            else:
                new[key] = scatter(c[key], pend[key])
        return new

    new_units = {name: commit_group(c, pending[name])
                 for name, c in cache["units"].items()}
    adv = jnp.where(active > 0, n_accept + 1, 0)
    out = {"pos": pos + adv, "block_tables": bt, "units": new_units}
    if "__dense__" in pending:
        out["dense"] = commit_group(cache["dense"], pending["__dense__"])
    return out


def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: RunCtx = RunCtx(),
                *, active: jax.Array | None = None):
    """One decode step: tokens (B, 1) [or (B, 1, n_cb)] + cache -> logits,
    updated cache.

    Two cache layouts share this seam, discriminated by pytree structure
    (keys are static under jit): the classic ring buffer (scalar ``pos``,
    per-slot ring per layer) and the paged layout from ``init_paged_cache``
    (per-slot ``pos``/``block_tables``, shared page pools).  ``active``
    applies to the paged layout only: it gates which slots advance."""
    if "block_tables" in cache:
        return _paged_decode_step(params, cache, tokens, cfg, ctx, active)
    pos = cache["pos"]
    x = embed_tokens(params, tokens, cfg, ctx)
    emb0 = x
    shared = params.get("shared_attn")

    new_dense = []
    for blk, c in zip(params.get("dense_layers", []), cache.get("dense", [])):
        x, c = _block_decode(blk, x, pos, c, cfg, ctx, window=cfg.sliding_window)
        new_dense.append(c)

    def body(x, xs):
        unit, c_unit = xs
        u = unit_size(cfg)
        new_c = {}
        for i in range(u):
            sub, c = unit[f"sub{i}"], c_unit[f"sub{i}"]
            if cfg.uses_ssm:
                h = common.rmsnorm(x, sub["pre_norm"], cfg.norm_eps)
                out, (conv, ssm_state) = ssm.mamba_decode(
                    sub, h, (c["conv"], c["ssm"]), cfg, constrain=ctx.constrain)
                x = x + out
                new_c[f"sub{i}"] = {"conv": conv.astype(c["conv"].dtype),
                                    "ssm": ssm_state.astype(c["ssm"].dtype)}
            else:
                window = cfg.window_for_layer(i)
                x, c2 = _block_decode(sub, x, pos, c, cfg, ctx, window=window)
                new_c[f"sub{i}"] = c2
        if shared is not None:
            h = jnp.concatenate([x, emb0], axis=-1) @ shared["w_in"].astype(x.dtype)
            sc = c_unit["__shared__"]
            out, sc2 = _block_decode(shared["block"], h, pos, sc, cfg, ctx,
                                     window=0)
            x = x + (out - h)
            new_c["__shared__"] = sc2
        return x, new_c

    units_cache = cache["units"]
    if shared is not None:
        units_cache = dict(units_cache)
        units_cache["__shared__"] = cache["shared"]
    x, new_units = jax.lax.scan(body, x, (params["layers"], units_cache))

    x = _norm(x, params["final_norm"], cfg)
    logits = lm_logits(params, x, cfg, ctx)

    new_cache = {"pos": pos + 1, "units": {k: v for k, v in new_units.items()
                                           if k != "__shared__"}}
    if shared is not None:
        new_cache["shared"] = new_units["__shared__"]
    if new_dense:
        new_cache["dense"] = new_dense
    return logits, new_cache
