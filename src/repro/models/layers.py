"""Feed-forward layers: dense SwiGLU MLP and expert-parallel MoE.

MoE dispatch is the TPU-native adaptation of GShard top-k routing:

  * tokens are batch-sharded over ("pod","data") and replicated over "model";
  * experts are sharded over "model" (EP).  Inside a shard_map, each model
    shard selects the tokens routed to ITS experts with a one-hot-cumsum
    capacity assignment (no all-to-all — selection is local because tokens
    are replicated on the model axis), runs its expert FFNs as one batched
    einsum (MXU-friendly (E_loc, Cap, d) x (E_loc, d, ff)), and the combine
    is a single psum over "model" — the same all-reduce pattern Megatron TP
    uses, so MoE adds no new collective phase.
  * shared experts (DeepSeek) are computed in the same shard_map with their
    hidden dim sliced over "model", folded into the same psum.

Without a mesh (CPU smoke tests) the same math runs with E_loc = E and the
psum elided — bit-identical routing decisions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.models.attention import ParamLeaf, pl_
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """How model code sees the mesh.  None mesh = single-process smoke path."""
    mesh: Any = None
    batch_axes: tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"
    data_axis: str = "data"
    moe_strategy: str = "gather"   # gather | a2a (see moe_forward_a2a)

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get(self.data_axis, 1)


NO_MESH = ParallelCtx()


# ==========================================================================
# dense SwiGLU MLP
# ==========================================================================
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict[str, Any]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = common.split_keys(key, 3)
    dt = cfg.param_dtype
    p = {
        "wi_up": pl_(k2, (d, ff), ("embed", "mlp"), dtype=dt),
        "wo": pl_(k3, (ff, d), ("mlp", "embed"), dtype=dt),
    }
    if cfg.gated_mlp:
        p["wi_gate"] = pl_(k1, (d, ff), ("embed", "mlp"), dtype=dt)
    return p


def mlp_forward(params, x, cfg: ModelConfig, constrain=None) -> jax.Array:
    adt = x.dtype
    act = common.activation(cfg.act)
    if "wi_gate" in params:
        h = act(x @ params["wi_gate"].astype(adt)) * (x @ params["wi_up"].astype(adt))
    else:
        h = act(x @ params["wi_up"].astype(adt))
    if constrain is not None:
        h = constrain(h, ("batch", None, "mlp_act"))
    out = h @ params["wo"].astype(adt)
    if constrain is not None:
        out = constrain(out, ("batch", None, "embed_act"))
    return out


# ==========================================================================
# MoE
# ==========================================================================
def init_moe(key, cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    E, ff = cfg.n_experts, cfg.resolved_moe_d_ff
    keys = common.split_keys(key, 6)
    dt = cfg.param_dtype
    # expert weights get DEDICATED logical axes so the sharding strategy can
    # re-map them without touching the rest of the model:
    #   gather: experts->model (EP), expert_d->data (FSDP), expert_ff->None
    #   a2a:    experts->data (EP ownership), expert_ff->model (Megatron
    #           within-expert TP), expert_d->None — zero weight gathers
    p = {
        "router": pl_(keys[0], (d, E), ("embed", None), dtype=dt),
        "wi_gate": pl_(keys[1], (E, d, ff),
                       ("experts", "expert_d", "expert_ff"), dtype=dt),
        "wi_up": pl_(keys[2], (E, d, ff),
                     ("experts", "expert_d", "expert_ff"), dtype=dt),
        "wo": pl_(keys[3], (E, ff, d),
                  ("experts", "expert_ff", "expert_d"), dtype=dt),
    }
    if cfg.n_shared_experts:
        sff = cfg.resolved_shared_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "wi_gate": pl_(keys[4], (d, sff), ("embed", "mlp"), dtype=dt),
            "wi_up": pl_(keys[5], (d, sff), ("embed", "mlp"), dtype=dt),
            "wo": pl_(common.split_keys(keys[4], 2)[1], (sff, d),
                      ("mlp", "embed"), dtype=dt),
        }
    return p


def _moe_local(x2d, gates, idx, wi_gate, wi_up, wo, shard_idx, E_loc,
               capacity, act, keep_dtype):
    """Dispatch + expert compute for the experts owned by this shard.

    x2d: (T, d) local tokens; gates/idx: (T, k) top-k routing.
    wi_*: (E_loc, d, ff) this shard's experts.  Returns (T, d) partial out.
    """
    T, d = x2d.shape
    k = idx.shape[1]
    lo = shard_idx * E_loc

    flat_e = idx.reshape(-1) - lo                       # (T*k,)
    sel = (flat_e >= 0) & (flat_e < E_loc)
    flat_e = jnp.where(sel, flat_e, 0)
    oh = jax.nn.one_hot(flat_e, E_loc, dtype=jnp.float32) * sel[:, None]
    pos = (jnp.cumsum(oh, axis=0) - oh) * oh            # (T*k, E_loc)
    pos_at = jnp.sum(pos, axis=1).astype(jnp.int32)     # position within expert
    keep = sel & (pos_at < capacity)

    tok = jnp.repeat(jnp.arange(T), k)
    slot = flat_e * capacity + pos_at                   # (T*k,)
    buf = jnp.zeros((E_loc * capacity, d), keep_dtype)
    contrib = x2d[tok] * keep[:, None].astype(keep_dtype)
    buf = buf.at[jnp.where(keep, slot, E_loc * capacity)].add(
        contrib, mode="drop", indices_are_sorted=False)
    buf = buf.reshape(E_loc, capacity, d)

    h = act(jnp.einsum("ecd,edf->ecf", buf, wi_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, wi_up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E_loc * capacity, d)

    gathered = out_buf[jnp.where(keep, slot, 0)] * keep[:, None].astype(keep_dtype)
    weighted = gathered * gates.reshape(-1)[:, None].astype(keep_dtype)
    out = jnp.zeros((T, d), keep_dtype).at[tok].add(weighted)
    return out


def moe_forward(params, x, cfg: ModelConfig, ctx: ParallelCtx = NO_MESH,
                constrain=None):
    """Top-k MoE FFN.  x: (B, S, d).  Returns (out, aux_loss)."""
    B, S, d = x.shape
    adt = x.dtype
    E, k = cfg.n_experts, cfg.experts_per_token
    act = common.activation(cfg.act)

    x2d = x.reshape(B * S, d)
    logits = (x2d @ params["router"].astype(adt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)             # (T, E)
    if cfg.route_group_limit and ctx.mesh is not None:
        # DeepSeek-V2 device-limited routing: each token's experts must live
        # on <= M device groups (chosen by the groups' summed affinity) —
        # this bounds the all-to-all fan-out to M ranks per token.
        n_groups = ctx.data_size if ctx.moe_strategy == "a2a" \
            else ctx.model_size
        if E % n_groups == 0 and n_groups > cfg.route_group_limit:
            gsz = E // n_groups
            gscore = probs.reshape(-1, n_groups, gsz).sum(-1)   # (T, G)
            _, top_g = jax.lax.top_k(gscore, cfg.route_group_limit)
            gmask = jnp.zeros_like(gscore).at[
                jnp.arange(gscore.shape[0])[:, None], top_g].set(1.0)
            probs = probs * jnp.repeat(gmask, gsz, axis=1)
    gates, idx = jax.lax.top_k(probs, k)                # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (computed identically on all shards)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.aux_loss_weight

    if (ctx.mesh is not None and ctx.moe_strategy == "a2a"
            and E % max(ctx.data_size, 1) == 0):
        return moe_forward_a2a(params, x, cfg, ctx, gates, idx, aux)

    tp = ctx.model_size
    E_loc = E // tp
    T_tot = B * S

    if ctx.mesh is None:
        capacity = _capacity(cfg, k, T_tot, E)
        out2d = _moe_local(x2d, gates, idx, params["wi_gate"].astype(adt),
                           params["wi_up"].astype(adt), params["wo"].astype(adt),
                           0, E, capacity, act, adt)
        if "shared" in params:
            out2d = out2d + _shared_expert(params["shared"], x2d, act, adt)
        return out2d.reshape(B, S, d), aux

    maxis = ctx.model_axis
    baxes = tuple(a for a in ctx.batch_axes if a in ctx.mesh.shape)
    n_batch_shards = 1
    for a in baxes:
        n_batch_shards *= ctx.mesh.shape[a]
    T_loc = T_tot // n_batch_shards
    capacity = _capacity(cfg, k, T_loc, E)
    bspec = P(baxes)          # shard dim 0 of (T, ...) over all batch axes

    def shard_fn(x2d_l, gates_l, idx_l, wig, wiu, wog, shared):
        sidx = jax.lax.axis_index(maxis)
        out = _moe_local(x2d_l, gates_l, idx_l, wig.astype(adt),
                         wiu.astype(adt), wog.astype(adt),
                         sidx, E_loc, capacity, act, adt)
        if shared is not None:
            out = out + _shared_expert(shared, x2d_l, act, adt)
        return jax.lax.psum(out, maxis)

    shared_p = params.get("shared")
    shared_specs = None
    if shared_p is not None:
        # shared-expert hidden dim sliced over model; psum restores full out
        shared_specs = {"wi_gate": P(None, maxis), "wi_up": P(None, maxis),
                        "wo": P(maxis, None)}

    out2d = common.shard_map(
        shard_fn, mesh=ctx.mesh,
        in_specs=(bspec, bspec, bspec,
                  P(maxis), P(maxis), P(maxis), shared_specs),
        out_specs=bspec,
        check=False,
    )(x2d, gates, idx, params["wi_gate"], params["wi_up"], params["wo"],
      shared_p)
    return out2d.reshape(B, S, d), aux


def _place(dest, sel, capacity, n_dest):
    """One-hot-cumsum slot assignment: returns (slot, keep) for scattering
    items into per-destination capacity buffers.  dest: (M,) ints; sel: (M,)
    bool.  slot in [0, n_dest*capacity)."""
    oh = jax.nn.one_hot(dest, n_dest, dtype=jnp.float32) * sel[:, None]
    pos = (jnp.cumsum(oh, axis=0) - oh) * oh
    pos_at = jnp.sum(pos, axis=1).astype(jnp.int32)
    keep = sel & (pos_at < capacity)
    slot = jnp.where(keep, dest * capacity + pos_at, n_dest * capacity)
    return slot, keep


def moe_forward_a2a(params, x, cfg: ModelConfig, ctx: ParallelCtx,
                    gates, idx, aux):
    """Dispatch-by-all-to-all expert parallelism (beyond-paper optimization).

    Layout: experts are OWNED by data ranks (E / n_data each) with their
    hidden dim ff sharded over the model axis (Megatron within-expert TP).
    Expert weights are therefore never gathered — the baseline "gather"
    strategy moves the full fp32 expert slab per layer per microbatch, which
    the dry-run showed dominating deepseek-v2's collective term.

    Per layer the wire cost is 2 token all-to-alls over "data" (send tokens
    to their experts' owners, return outputs) + 1 psum over "model" — token
    bytes instead of weight bytes.
    """
    B, S, d = x.shape
    adt = x.dtype
    E, k = cfg.n_experts, cfg.experts_per_token
    act = common.activation(cfg.act)
    mesh = ctx.mesh
    daxis, maxis = ctx.data_axis, ctx.model_axis
    n_data = ctx.data_size
    E_loc = E // n_data

    baxes = tuple(a for a in ctx.batch_axes if a in mesh.shape)
    n_batch_shards = 1
    for a in baxes:
        n_batch_shards *= mesh.shape[a]
    T_l = (B * S) // n_batch_shards                  # tokens per device
    # (token, dest) copies are DEDUPED, so the per-token wire fan-out is
    # min(k, n_data) — and route_group_limit (DeepSeek device-limited
    # routing) bounds it to M.  Capacities follow the effective fan-out.
    fan = min(k, n_data)
    if cfg.route_group_limit:
        fan = min(fan, cfg.route_group_limit)
    if T_l <= 256:                                    # decode/smoke: lossless
        cap_send = T_l * fan
        cap_exp = n_data * cap_send
    else:
        cap_send = max(1, int(cfg.capacity_factor * fan * T_l / n_data))
        cap_exp = max(1, int(cfg.capacity_factor * k * T_l * n_data / E))

    x2d = x.reshape(B * S, d)
    bspec = P(baxes)

    def shard_fn(x_l, gates_l, idx_l, wig, wiu, wog, shared):
        T, _ = x_l.shape
        dest = idx_l // E_loc                        # (T, k) owning data rank
        local_e = idx_l % E_loc

        # ---- dedup (token, dest) pairs: a token whose experts share an
        # owner is sent ONCE, carrying a gate VECTOR over that owner's
        # E_loc experts.  With DeepSeek-style device-limited routing
        # (route_group_limit = M) this bounds wire copies to M per token.
        first = jnp.ones((T, k), bool)
        for j in range(1, k):
            dup = jnp.zeros((T,), bool)
            for i in range(j):
                dup |= dest[:, j] == dest[:, i]
            first = first.at[:, j].set(~dup)
        # per-(token,k): gate vector contribution to (dest, local_e)
        flat_dest = dest.reshape(-1)
        flat_first = first.reshape(-1)
        tok = jnp.repeat(jnp.arange(T), k)

        slot, keep = _place(flat_dest, flat_first, cap_send, n_data)
        kf = keep[:, None].astype(adt)
        # map every (token,k) pair to the slot of its (token,dest) copy:
        # pairs suppressed by dedup reuse the FIRST copy's slot
        slot_map = jnp.full((T, n_data), n_data * cap_send, jnp.int32)
        slot_map = slot_map.at[tok, flat_dest].min(
            jnp.where(keep, slot, n_data * cap_send))
        pair_slot = slot_map[tok, flat_dest]          # (T*k,)
        pair_ok = pair_slot < n_data * cap_send

        send_x = jnp.zeros((n_data * cap_send, d), adt) \
            .at[slot].add(x_l[tok] * kf, mode="drop")
        # gate payload: (slots, E_loc) accumulated over the pairs
        send_g = jnp.zeros((n_data * cap_send, E_loc), adt) \
            .at[jnp.where(pair_ok, pair_slot, n_data * cap_send),
                local_e.reshape(-1)].add(
                gates_l.reshape(-1).astype(adt) * pair_ok, mode="drop")

        recv_x = jax.lax.all_to_all(send_x.reshape(n_data, cap_send, d),
                                    daxis, 0, 0, tiled=False)
        recv_g = jax.lax.all_to_all(send_g.reshape(n_data, cap_send, E_loc),
                                    daxis, 0, 0, tiled=False)

        # ---- dispatch received copies into my experts' buffers -------------
        rx = recv_x.reshape(-1, d)                   # (R, d)
        rg = recv_g.reshape(-1, E_loc)               # (R, E_loc)
        R = rx.shape[0]
        # every (copy, local expert) with nonzero gate is an assignment
        a_e = jnp.tile(jnp.arange(E_loc), R)
        a_copy = jnp.repeat(jnp.arange(R), E_loc)
        a_gate = rg.reshape(-1)
        sel2 = a_gate != 0
        slot2, keep2 = _place(a_e, sel2, cap_exp, E_loc)
        buf = jnp.zeros((E_loc * cap_exp, d), adt) \
            .at[slot2].add(rx[a_copy] * keep2[:, None].astype(adt),
                           mode="drop")
        buf = buf.reshape(E_loc, cap_exp, d)

        # ---- expert compute, ff sharded over model (partial sums) ----------
        h = act(jnp.einsum("ecd,edf->ecf", buf, wig.astype(adt))) * \
            jnp.einsum("ecd,edf->ecf", buf, wiu.astype(adt))
        out_buf = jnp.einsum("ecf,efd->ecd", h, wog.astype(adt)) \
            .reshape(E_loc * cap_exp, d)

        # ---- gate-weighted combine per copy, then return trip ----------------
        got = out_buf.at[jnp.where(keep2, slot2, 0)].get() \
            * (a_gate * keep2)[:, None].astype(adt)
        back = jnp.zeros((R, d), adt).at[a_copy].add(got)
        back = jax.lax.all_to_all(back.reshape(n_data, cap_send, d),
                                  daxis, 0, 0, tiled=False)
        back = back.reshape(n_data * cap_send, d)
        # copies are already gate-weighted; sum each token's copies
        copy_out = back.at[jnp.where(keep, slot, 0)].get() * kf
        out = jnp.zeros((T, d), adt).at[tok].add(
            copy_out * flat_first[:, None].astype(adt))

        # out is PARTIAL over the model axis (ff sharded); shared experts
        # contribute their own ff-sharded partial — one fused psum
        if shared is not None:
            out = out + _shared_expert(shared, x_l, act, adt)
        return jax.lax.psum(out, maxis)

    shared_p = params.get("shared")
    shared_specs = None
    if shared_p is not None:
        shared_specs = {"wi_gate": P(None, maxis), "wi_up": P(None, maxis),
                        "wo": P(maxis, None)}

    out2d = common.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(bspec, bspec, bspec,
                  P(daxis, None, maxis), P(daxis, None, maxis),
                  P(daxis, maxis, None), shared_specs),
        out_specs=bspec,
        check=False,
    )(x2d, gates, idx, params["wi_gate"], params["wi_up"], params["wo"],
      shared_p)
    return out2d.reshape(B, S, d), aux


def _capacity(cfg: ModelConfig, k: int, T: int, E: int) -> int:
    """Expert capacity.  Token dropping is part of capacity-based routing
    during training, but decode steps (tiny T) must never drop — a dropped
    token in serving is a quality bug, and the buffer is tiny anyway."""
    if T <= 256:
        return T
    return max(1, min(T, int(cfg.capacity_factor * k * T / E)))


def _shared_expert(shared, x2d, act, adt):
    h = act(x2d @ shared["wi_gate"].astype(adt)) * (x2d @ shared["wi_up"].astype(adt))
    return h @ shared["wo"].astype(adt)
