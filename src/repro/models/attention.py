"""Attention blocks: GQA (with RoPE / sliding-window / logit softcap) and
DeepSeek-V2 MLA (multi-head latent attention, decoupled RoPE, absorbed decode).

Every init function returns a pytree whose leaves are ``ParamLeaf(array,
logical_axes)``; ``repro.runtime.sharding`` resolves logical axes ("embed",
"q_heads", "mlp", "experts", "vocab", ...) to mesh axes per architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import quant
from repro.kernels import ops
from repro.models import common
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# param leaves with logical sharding axes
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ParamLeaf:
    array: jax.Array
    axes: tuple[str | None, ...]


jax.tree_util.register_pytree_node(
    ParamLeaf,
    lambda leaf: ((leaf.array,), leaf.axes),
    lambda axes, children: ParamLeaf(children[0], axes),
)


def pl_(key, shape, axes, std=None, dtype="float32") -> ParamLeaf:
    arr = (common.fan_in_init(key, shape, dtype=dtype) if std is None
           else common.normal_init(key, shape, std, dtype=dtype))
    return ParamLeaf(arr, axes)


def split_leaves(tree):
    """(params_with_leaves) -> (raw_param_tree, logical_axes_tree)."""
    is_leaf = lambda x: isinstance(x, ParamLeaf)
    params = jax.tree.map(lambda l: l.array, tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=is_leaf)
    return params, axes


# ==========================================================================
# GQA attention
# ==========================================================================
def init_gqa(key, cfg: ModelConfig) -> dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.padded_q_heads, cfg.padded_kv_heads
    kq, kk, kv, ko = common.split_keys(key, 4)
    dt = cfg.param_dtype
    return {
        "wq": pl_(kq, (d, hq, hd), ("embed", "q_heads", None), dtype=dt),
        "wk": pl_(kk, (d, hkv, hd), ("embed", "kv_heads", None), dtype=dt),
        "wv": pl_(kv, (d, hkv, hd), ("embed", "kv_heads", None), dtype=dt),
        "wo": pl_(ko, (hq, hd, d), ("q_heads", None, "embed"), dtype=dt),
    }


def _mask_padded_heads(o, cfg: ModelConfig):
    """Zero the padded heads' outputs: their wq/wk/wv/wo slices then receive
    zero gradient, so the math is exactly the published n_heads model."""
    if not cfg.heads_padded:
        return o
    mask = (jnp.arange(cfg.padded_q_heads) < cfg.n_heads)
    return o * mask[..., None].astype(o.dtype)


def gqa_forward(params, x, positions, cfg: ModelConfig, *, window: int = 0,
                policy: ops.KernelPolicy = ops.DEFAULT_POLICY,
                constrain=None) -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, S, d)."""
    adt = x.dtype
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    if constrain is not None:
        q = constrain(q, ("batch", None, "q_heads", None))
        k = constrain(k, ("batch", None, "kv_heads", None))
        v = constrain(v, ("batch", None, "kv_heads", None))
    q = common.apply_rope_partial(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = common.apply_rope_partial(k, positions, cfg.rope_theta, cfg.rope_fraction)
    scale = cfg.query_scale or hd ** -0.5
    o = ops.attention(q, k, v, causal=True, window=window,
                      logit_cap=cfg.attn_logit_softcap, scale=scale,
                      policy=policy)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    if constrain is not None:
        out = constrain(out, ("batch", None, "embed_act"))
    return out


def gqa_prefill(params, x, positions, cfg: ModelConfig, *, window: int = 0,
                cache_len: int, policy=ops.DEFAULT_POLICY, constrain=None):
    """Prefill: same as forward but also returns (k, v) laid into a cache of
    capacity ``cache_len`` (ring layout, slot = pos % cache_len)."""
    adt = x.dtype
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    k = common.apply_rope_partial(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    q = common.apply_rope_partial(q, positions, cfg.rope_theta, cfg.rope_fraction)
    scale = cfg.query_scale or cfg.resolved_head_dim ** -0.5
    o = ops.attention(q, k, v, causal=True, window=window,
                      logit_cap=cfg.attn_logit_softcap, scale=scale,
                      policy=policy)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))

    S = x.shape[1]
    if cache_len >= S:
        pad = cache_len - S
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:  # ring: keep the last cache_len entries at slot pos % cache_len
        keep_k, keep_v = k[:, -cache_len:], v[:, -cache_len:]
        shift = S % cache_len
        k_c = jnp.roll(keep_k, shift, axis=1)
        v_c = jnp.roll(keep_v, shift, axis=1)
    return out, (k_c, v_c)


def gqa_decode(params, x, pos, cache_kv, cfg: ModelConfig, *, window: int = 0,
               policy: ops.KernelPolicy = ops.DEFAULT_POLICY, constrain=None):
    """One-token decode. x: (B, 1, d); cache_kv = (k, v) ring buffers of
    capacity C; pos: () int32 absolute position of the new token."""
    adt = x.dtype
    k_cache, v_cache = cache_kv
    C = k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    posb = jnp.asarray(pos)[None]
    q = common.apply_rope_partial(q, posb, cfg.rope_theta, cfg.rope_fraction)
    k = common.apply_rope_partial(k, posb, cfg.rope_theta, cfg.rope_fraction)
    slot = jnp.mod(pos, C)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, slot, 0, 0))
    scale = cfg.query_scale or cfg.resolved_head_dim ** -0.5
    o = ops.decode_attention(q, k_cache, v_cache, pos, window=window,
                             logit_cap=cfg.attn_logit_softcap, scale=scale,
                             policy=policy)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    return out, (k_cache, v_cache)


def gqa_decode_paged(params, x, pos, cache_kv, block_tables, cfg: ModelConfig,
                     *, window: int = 0,
                     policy: ops.KernelPolicy = ops.DEFAULT_POLICY,
                     constrain=None):
    """One-token decode against a paged KV cache.  x: (B, 1, d);
    cache_kv = (k_pages, v_pages) pools of shape (P, ps, Hkv, *);
    block_tables: (B, nb) physical page per logical block; pos: (B,)
    per-request absolute position of the new token (the batch is ragged —
    every slot of the continuous-batching engine sits at its own depth).

    The new k/v row is scattered into physical row
    ``block_tables[b, pos[b] // ps] * ps + pos[b] % ps`` of the flattened
    pool — slots parked on their scratch page by the engine overwrite that
    scratch harmlessly.

    ``cache_kv`` may also be a 4-tuple ``(k_pages, v_pages, k_scales,
    v_scales)`` (int8 pools, per-row fp32 scales): the new row is
    quantized per (kv-head) row before the scatter, its scale lands at
    the same physical row, and the scales ride into the attention sweep
    for fused dequant.  Returns the cache in the same arity it came."""
    adt = x.dtype
    if len(cache_kv) == 4:
        k_pages, v_pages, k_scales, v_scales = cache_kv
    else:
        k_pages, v_pages = cache_kv
        k_scales = v_scales = None
    P, ps = k_pages.shape[0], k_pages.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    posb = jnp.asarray(pos)[:, None]                       # (B, 1)
    q = common.apply_rope_partial(q, posb, cfg.rope_theta, cfg.rope_fraction)
    k = common.apply_rope_partial(k, posb, cfg.rope_theta, cfg.rope_fraction)
    page = jnp.take_along_axis(block_tables, pos[:, None] // ps, axis=1)[:, 0]
    row = page * ps + pos % ps                             # (B,)
    k_row, v_row = k[:, 0], v[:, 0]                        # (B, Hkv, hd)
    if k_scales is not None:
        k_row, ks_row = quant.quantize_int8_rows(k_row)
        v_row, vs_row = quant.quantize_int8_rows(v_row)
        ks_flat = k_scales.reshape(P * ps, *k_scales.shape[2:])
        vs_flat = v_scales.reshape(P * ps, *v_scales.shape[2:])
        k_scales = ks_flat.at[row].set(ks_row).reshape(k_scales.shape)
        v_scales = vs_flat.at[row].set(vs_row).reshape(v_scales.shape)
    k_flat = k_pages.reshape(P * ps, *k_pages.shape[2:])
    v_flat = v_pages.reshape(P * ps, *v_pages.shape[2:])
    k_flat = k_flat.at[row].set(k_row.astype(k_flat.dtype))
    v_flat = v_flat.at[row].set(v_row.astype(v_flat.dtype))
    k_pages = k_flat.reshape(k_pages.shape)
    v_pages = v_flat.reshape(v_pages.shape)
    scale = cfg.query_scale or cfg.resolved_head_dim ** -0.5
    o = ops.paged_decode_attention(q, k_pages, v_pages, block_tables, pos,
                                   window=window,
                                   logit_cap=cfg.attn_logit_softcap,
                                   scale=scale, policy=policy,
                                   k_scale=k_scales, v_scale=v_scales)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    if k_scales is not None:
        return out, (k_pages, v_pages, k_scales, v_scales)
    return out, (k_pages, v_pages)


def gqa_decode_ragged(params, x, pos, cache_kv, cfg: ModelConfig, *,
                      window: int = 0,
                      policy: ops.KernelPolicy = ops.DEFAULT_POLICY,
                      constrain=None):
    """One-token decode against per-slot PRIVATE ring buffers at ragged
    positions.  x: (B, 1, d); cache_kv = (k, v) of shape (B, C, Hkv, *);
    pos: (B,) per-request absolute position of the new token.

    This is the paged engine's windowed-layer decode: a sliding-window
    layer never needs more than the last ``window`` tokens, so its "page
    table" is a static identity map over ``ceil(window/ps)`` pages per
    slot and the pages form a ring of capacity C = ceil(window/ps)*ps —
    O(window) latent bytes per slot regardless of sequence depth.  Each
    batch row writes its own ring slot ``pos[b] % C``; the attention sweep
    masks per-row (``ops.decode_attention`` accepts the ragged ``pos``
    directly on both the Pallas and jnp backends)."""
    adt = x.dtype
    k_cache, v_cache = cache_kv
    B, C = k_cache.shape[0], k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    posb = jnp.asarray(pos)[:, None]                       # (B, 1)
    q = common.apply_rope_partial(q, posb, cfg.rope_theta, cfg.rope_fraction)
    k = common.apply_rope_partial(k, posb, cfg.rope_theta, cfg.rope_fraction)
    rows = jnp.arange(B)
    slot = jnp.mod(pos, C)                                 # (B,)
    k_cache = k_cache.at[rows, slot].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[rows, slot].set(v[:, 0].astype(v_cache.dtype))
    scale = cfg.query_scale or cfg.resolved_head_dim ** -0.5
    o = ops.decode_attention(q, k_cache, v_cache, pos, window=window,
                             logit_cap=cfg.attn_logit_softcap, scale=scale,
                             policy=policy)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    return out, (k_cache, v_cache)


def gqa_verify(params, x, pos, cache_kv, cfg: ModelConfig, *, window: int = 0,
               policy: ops.KernelPolicy = ops.DEFAULT_POLICY, constrain=None):
    """Speculative verify: score ``Q = K+1`` fed tokens in one cache sweep.

    x: (B, Q, d) — the fed block [t_last, d_1..d_K] at positions
    ``pos .. pos+Q-1``; cache_kv = (k, v) ring buffers committed through
    ``pos - 1``.  Unlike ``gqa_decode``, NOTHING is written to the cache:
    the block's own k/v are returned as *pending* rows for the runtime to
    commit once the accepted prefix is known — rejection needs no rollback,
    and a wrapped ring's history stays intact for re-drafting."""
    adt = x.dtype
    k_cache, v_cache = cache_kv
    Q = x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    posq = jnp.asarray(pos)[None] + jnp.arange(Q)[None, :]   # (1, Q)
    q = common.apply_rope_partial(q, posq, cfg.rope_theta, cfg.rope_fraction)
    k = common.apply_rope_partial(k, posq, cfg.rope_theta, cfg.rope_fraction)
    scale = cfg.query_scale or cfg.resolved_head_dim ** -0.5
    o = ops.verify_attention(q, k_cache, v_cache, k, v, pos, window=window,
                             logit_cap=cfg.attn_logit_softcap, scale=scale,
                             policy=policy)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    return out, (k, v)


def gqa_verify_paged(params, x, pos, cache_kv, block_tables, cfg: ModelConfig,
                     *, window: int = 0,
                     policy: ops.KernelPolicy = ops.DEFAULT_POLICY,
                     constrain=None):
    """Paged analogue of ``gqa_verify``: per-request ``pos`` (B,), shared
    page pools committed through ``pos[b] - 1``.  The pending rows are
    returned for a masked per-slot commit — pools stay untouched here.

    Besides speculative verify, this is the sweep behind **chunked paged
    prefill** (``transformer.prefill_suffix``): a prompt-suffix chunk at
    positions ``pos .. pos+Q-1`` attending to a prefix the cache already
    holds (possibly on pages shared read-only with other slots) is the
    same computation with every row "accepted" at commit time.

    ``cache_kv`` may be the 4-tuple int8 form (see ``gqa_decode_paged``);
    the scales are read-only here — pending rows stay unquantized and are
    quantized (if at all) by ``commit_spec_paged``.  The SWEEP, however,
    must see the in-flight rows at cache precision: a decode step commits
    its row before attending (so it reads the dequantized value), and a
    chunk boundary moves rows between "committed" and "in-flight" — if
    the in-flight side rode through raw, logits would depend on where the
    chunk boundary fell and requeue replay would not be bit-exact.  So the
    candidates are round-tripped through the row quantizer here, exactly
    the (q, scale) pair the commit will write."""
    adt = x.dtype
    if len(cache_kv) == 4:
        k_pages, v_pages, k_scales, v_scales = cache_kv
    else:
        k_pages, v_pages = cache_kv
        k_scales = v_scales = None
    Q = x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(adt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(adt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(adt))
    posq = jnp.asarray(pos)[:, None] + jnp.arange(Q)[None, :]  # (B, Q)
    q = common.apply_rope_partial(q, posq, cfg.rope_theta, cfg.rope_fraction)
    k = common.apply_rope_partial(k, posq, cfg.rope_theta, cfg.rope_fraction)
    scale = cfg.query_scale or cfg.resolved_head_dim ** -0.5
    if k_scales is not None:
        k_sweep = quant.dequantize_int8_rows(*quant.quantize_int8_rows(k))
        v_sweep = quant.dequantize_int8_rows(*quant.quantize_int8_rows(v))
    else:
        k_sweep, v_sweep = k, v
    o = ops.paged_verify_attention(q, k_pages, v_pages, k_sweep, v_sweep,
                                   block_tables,
                                   pos, window=window,
                                   logit_cap=cfg.attn_logit_softcap,
                                   scale=scale, policy=policy,
                                   k_scale=k_scales, v_scale=v_scales)
    o = _mask_padded_heads(o, cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    return out, (k, v)


# ==========================================================================
# MLA (DeepSeek-V2)
# ==========================================================================
def init_mla(key, cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    H = cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    keys = common.split_keys(key, 8)
    dt = cfg.param_dtype
    p: dict[str, Any] = {
        # latent kv down-projection (+ shared rope key)
        "wdkv": pl_(keys[0], (d, r_kv + dr), ("embed", None), dtype=dt),
        "kv_norm": ParamLeaf(common.ones((r_kv,), dt), (None,)),
        # up-projections from the latent
        "wuk": pl_(keys[1], (r_kv, H, dn), (None, "q_heads", None), dtype=dt),
        "wuv": pl_(keys[2], (r_kv, H, dv), (None, "q_heads", None), dtype=dt),
        "wo": pl_(keys[3], (H, dv, d), ("q_heads", None, "embed"), dtype=dt),
    }
    if r_q:
        p["wdq"] = pl_(keys[4], (d, r_q), ("embed", None), dtype=dt)
        p["q_norm"] = ParamLeaf(common.ones((r_q,), dt), (None,))
        p["wuq"] = pl_(keys[5], (r_q, H, dn + dr), (None, "q_heads", None), dtype=dt)
    else:
        p["wuq"] = pl_(keys[5], (d, H, dn + dr), ("embed", "q_heads", None), dtype=dt)
    return p


def _mla_queries(params, x, positions, cfg: ModelConfig):
    adt = x.dtype
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["wdq"].astype(adt))
        cq = common.rmsnorm(cq, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"].astype(adt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wuq"].astype(adt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params, x, positions, cfg: ModelConfig):
    """Down-project to the compressed latent: returns (c_kv, k_rope)."""
    adt = x.dtype
    r_kv = cfg.kv_lora_rank
    ckv_rope = jnp.einsum("bsd,dr->bsr", x, params["wdkv"].astype(adt))
    c_kv, k_rope = ckv_rope[..., :r_kv], ckv_rope[..., r_kv:]
    c_kv = common.rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = common.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(params, x, positions, cfg: ModelConfig, *,
                policy=ops.DEFAULT_POLICY, constrain=None,
                return_latent: bool = False):
    """Training/prefill MLA: expand the latent to per-head k/v, run GQA-style
    flash attention with concatenated [nope|rope] q/k."""
    adt = x.dtype
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_queries(params, x, positions, cfg)
    c_kv, k_rope = _mla_latent(params, x, positions, cfg)

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wuk"].astype(adt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wuv"].astype(adt))
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_rope.shape[:2], H, dr))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    if constrain is not None:
        q = constrain(q, ("batch", None, "q_heads", None))
        k = constrain(k, ("batch", None, "q_heads", None))
        v = constrain(v, ("batch", None, "q_heads", None))
    scale = cfg.query_scale or (dn + dr) ** -0.5
    o = ops.attention(q, k, v, causal=True, scale=scale,
                      logit_cap=cfg.attn_logit_softcap, policy=policy)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(adt))
    if constrain is not None:
        out = constrain(out, ("batch", None, "embed_act"))
    if return_latent:
        return out, (c_kv, k_rope)
    return out


def mla_prefill(params, x, positions, cfg: ModelConfig, *, cache_len: int,
                policy=ops.DEFAULT_POLICY, constrain=None):
    """Prefill that also emits the compressed (c_kv, k_rope) cache — the whole
    point of MLA: the cache is rank r_kv + d_rope per token, not H*(dk+dv)."""
    out, (c_kv, k_rope) = mla_forward(params, x, positions, cfg, policy=policy,
                                      constrain=constrain, return_latent=True)
    S = x.shape[1]
    lat = jnp.concatenate([c_kv, k_rope], axis=-1)       # (B, S, r_kv + dr)
    if cache_len >= S:
        lat = jnp.pad(lat, ((0, 0), (0, cache_len - S), (0, 0)))
    else:
        lat = jnp.roll(lat[:, -cache_len:], S % cache_len, axis=1)
    return out, lat


def _mla_expand(params, o_lat, cfg: ModelConfig, adt):
    """Re-expand latent attention outputs through W_uv then W_o.
    o_lat: (B, H, r_kv) -> (B, 1, d)."""
    o = jnp.einsum("bhr,rhk->bhk", o_lat, params["wuv"].astype(adt))
    return jnp.einsum("bhk,hkd->bd", o, params["wo"].astype(adt))[:, None]


def mla_decode(params, x, pos, cache_lat, cfg: ModelConfig, *,
               policy: ops.KernelPolicy = ops.DEFAULT_POLICY, constrain=None):
    """Absorbed-matmul decode: score via q_nope @ W_uk acting on the latent
    cache directly; attention output re-expanded with W_uv afterwards.  The
    attend body is ``ops.mla_absorbed_attend_jnp`` — the SAME body the
    paged jnp path runs, which is what keeps paged greedy streams on the
    ring reference's argmax."""
    adt = x.dtype
    r_kv, dr, dn = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim
    C = cache_lat.shape[1]
    posb = jnp.asarray(pos)[None]
    q_nope, q_rope = _mla_queries(params, x, posb, cfg)      # (B,1,H,*)
    c_kv, k_rope = _mla_latent(params, x, posb, cfg)         # (B,1,r_kv),(B,1,dr)

    lat_t = jnp.concatenate([c_kv, k_rope], axis=-1)
    slot = jnp.mod(pos, C)
    cache_lat = jax.lax.dynamic_update_slice(
        cache_lat, lat_t.astype(cache_lat.dtype), (0, slot, 0))

    # absorb W_uk into the query:  (B,1,H,dn) @ (r,H,dn) -> (B,H,r)
    q_abs = jnp.einsum("bshk,rhk->bhr", q_nope, params["wuk"].astype(adt))
    scale = cfg.query_scale or (dn + dr) ** -0.5
    k_pos = pos - jnp.mod(pos - jnp.arange(C), C)
    valid = (k_pos >= 0) & (k_pos <= pos)
    o_lat = ops.mla_absorbed_attend_jnp(
        q_abs, q_rope[:, 0], cache_lat[..., :r_kv].astype(adt),
        cache_lat[..., r_kv:].astype(adt),
        jnp.broadcast_to(valid[None], (x.shape[0], C)),
        scale=scale, logit_cap=cfg.attn_logit_softcap)
    return _mla_expand(params, o_lat, cfg, adt), cache_lat


def mla_decode_paged(params, x, pos, cache_lat, block_tables,
                     cfg: ModelConfig, *,
                     policy: ops.KernelPolicy = ops.DEFAULT_POLICY,
                     constrain=None):
    """One-token absorbed-matmul MLA decode against a PAGED latent pool —
    the model zoo's compressed-KV headline.  x: (B, 1, d);
    cache_lat: (P, ps, R) latent page pool, R = kv_lora_rank +
    rope_head_dim (ONE row per token, every head shares it — ~5x fewer KV
    bytes than the dense-GQA layout); block_tables: (B, nb); pos: (B,)
    ragged per-request position.

    The new latent row is scattered at physical row
    ``block_tables[b, pos[b] // ps] * ps + pos[b] % ps`` (linear layout —
    same scheme as ``gqa_decode_paged``), then the whole query block
    [q_abs | q_rope] sweeps the pool through ``ops.mla_decode_paged``:
    the latent row serves scores AND values, so one page DMA feeds all
    heads."""
    adt = x.dtype
    r_kv, dr, dn = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim
    P, ps, R = cache_lat.shape
    posb = jnp.asarray(pos)[:, None]                       # (B, 1)
    q_nope, q_rope = _mla_queries(params, x, posb, cfg)    # (B,1,H,*)
    c_kv, k_rope = _mla_latent(params, x, posb, cfg)

    lat_row = jnp.concatenate([c_kv, k_rope], axis=-1)[:, 0]     # (B, R)
    page = jnp.take_along_axis(block_tables, pos[:, None] // ps, axis=1)[:, 0]
    row = page * ps + pos % ps
    lat_flat = cache_lat.reshape(P * ps, R)
    cache_lat = lat_flat.at[row].set(
        lat_row.astype(lat_flat.dtype)).reshape(P, ps, R)

    q_abs = jnp.einsum("bshk,rhk->bhr", q_nope, params["wuk"].astype(adt))
    q_lat = jnp.concatenate([q_abs[:, None], q_rope], axis=-1)   # (B,1,H,R)
    scale = cfg.query_scale or (dn + dr) ** -0.5
    o_lat = ops.mla_decode_paged(q_lat, cache_lat, block_tables, pos,
                                 r_kv=r_kv, scale=scale,
                                 logit_cap=cfg.attn_logit_softcap,
                                 policy=policy)                  # (B,1,H,r_kv)
    return _mla_expand(params, o_lat[:, 0], cfg, adt), cache_lat


def mla_verify_paged(params, x, pos, cache_lat, block_tables,
                     cfg: ModelConfig, *,
                     policy: ops.KernelPolicy = ops.DEFAULT_POLICY,
                     constrain=None):
    """Multi-query MLA sweep over the paged latent pool (speculative verify
    AND chunked paged prefill — same two callers as ``gqa_verify_paged``).

    No dedicated kernel: in absorbed form the latent pool IS a GQA cache
    with a single shared kv head — k_pages = the pool with an inserted
    head axis (P, ps, 1, R), v_pages = its first r_kv lanes, queries =
    [q_abs | q_rope] (B, Q, H, R) grouped G = H onto that one head — so
    the generic ``ops.paged_verify_attention`` sweep (and its Pallas
    kernel) serves MLA unchanged.  Returns the pending latent rows
    (B, Q, R) for the caller's masked commit; the pool stays untouched."""
    adt = x.dtype
    r_kv, dr, dn = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim
    Q = x.shape[1]
    posq = jnp.asarray(pos)[:, None] + jnp.arange(Q)[None, :]    # (B, Q)
    q_nope, q_rope = _mla_queries(params, x, posq, cfg)          # (B,Q,H,*)
    c_kv, k_rope = _mla_latent(params, x, posq, cfg)

    lat_new = jnp.concatenate([c_kv, k_rope], axis=-1)           # (B, Q, R)
    q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["wuk"].astype(adt))
    q_lat = jnp.concatenate([q_abs, q_rope], axis=-1)            # (B,Q,H,R)
    scale = cfg.query_scale or (dn + dr) ** -0.5
    o_lat = ops.paged_verify_attention(
        q_lat, cache_lat[:, :, None, :], cache_lat[:, :, None, :r_kv],
        lat_new[:, :, None, :].astype(adt),
        lat_new[:, :, None, :r_kv].astype(adt),
        block_tables, pos, scale=scale,
        logit_cap=cfg.attn_logit_softcap, policy=policy)         # (B,Q,H,r_kv)
    o = jnp.einsum("bqhr,rhk->bqhk", o_lat, params["wuv"].astype(adt))
    out = jnp.einsum("bqhk,hkd->bqd", o, params["wo"].astype(adt))
    return out, lat_new
