"""Model configuration — one dataclass covering every assigned family.

The 10 assigned architectures span dense transformers (GQA / sliding-window /
local-global alternation / logit softcaps), MoE (GShard top-k, shared experts,
DeepSeek MLA), pure SSM (Mamba2 SSD), hybrid (Zamba2: Mamba2 backbone with a
*shared* attention block), and modality backbones (MusicGen audio codes,
LLaVA vision-prefix).  One config type keeps the runtime/launcher generic:
every feature is off by default and enabled per-arch in repro/configs/.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "mamba"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # -- identity ------------------------------------------------------------
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | audio | vlm
    # -- trunk ----------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2               # query heads (0 for attention-free archs)
    n_kv_heads: int = 2            # GQA kv heads
    d_ff: int = 256                # MLP hidden (per-expert hidden when MoE)
    vocab_size: int = 256
    head_dim: int = 0              # 0 -> d_model // n_heads
    max_seq_len: int = 4096
    # -- attention flavour -----------------------------------------------------
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0         # stablelm2: partial rotary (0.25)
    sliding_window: int = 0            # >0 -> SWA on every attn layer (danube3)
    local_global: bool = False         # gemma2: alternate local/global layers
    local_window: int = 4096           # window of the local layers
    attn_logit_softcap: float = 0.0    # gemma2: tanh softcap on attn logits
    final_logit_softcap: float = 0.0   # gemma2: tanh softcap on LM logits
    query_scale: float = 0.0           # 0 -> 1/sqrt(head_dim)
    # TP compute padding (beyond-paper perf lever): run attention with the
    # head axes padded up to a multiple of the model-axis size so q/o
    # projections shard 16-way.  Padded heads are MASKED after attention, so
    # the math is exactly the published n_heads model (their params receive
    # zero gradient and stay at init).  0 = off.
    pad_q_heads_to: int = 0
    pad_kv_heads_to: int = 0
    # -- MLA (DeepSeek-V2) ------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0               # 0 -> no query compression
    rope_head_dim: int = 64            # decoupled RoPE key/query dims
    nope_head_dim: int = 128           # per-head non-rotary qk dim
    v_head_dim: int = 128
    # -- MoE ---------------------------------------------------------------------
    n_experts: int = 0                 # routed experts (0 -> dense MLP)
    n_shared_experts: int = 0
    experts_per_token: int = 0         # top-k
    moe_d_ff: int = 0                  # routed-expert hidden (0 -> d_ff)
    shared_d_ff: int = 0               # shared-expert hidden (0 -> moe_d_ff)
    first_dense_layers: int = 0        # DeepSeek: leading dense layers
    dense_d_ff: int = 0                # hidden of those dense layers (0 -> d_ff)
    router_noise: float = 0.0
    route_group_limit: int = 0         # DeepSeek-V2 device-limited routing:
                                       # experts from <= M device groups
    capacity_factor: float = 1.25      # expert capacity = cf * tokens/expert
    aux_loss_weight: float = 0.001     # load-balance loss
    # -- SSM (Mamba2 SSD) ----------------------------------------------------------
    ssm_state: int = 0                 # N (state size per head); 0 -> no ssm
    ssm_heads: int = 0                 # 0 -> d_inner // ssm_head_dim
    ssm_head_dim: int = 64             # P
    ssm_expand: int = 2                # d_inner = expand * d_model
    ssm_groups: int = 1                # B/C groups (like kv heads)
    ssm_chunk: int = 128               # SSD chunk length Q
    conv_width: int = 4
    # -- hybrid (Zamba2) --------------------------------------------------------
    hybrid_attn_every: int = 0         # shared attn block after every k mamba layers
    # -- modality backbones --------------------------------------------------------
    n_codebooks: int = 0               # musicgen: parallel EnCodec streams
    vision_tokens: int = 0             # llava: prefix patch-embedding slots
    # -- numerics / misc ---------------------------------------------------------
    act: str = "silu"                  # silu | gelu
    gated_mlp: bool = True             # False: classic 2-matrix MLP (musicgen)
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # gemma-style extras
    post_norms: bool = False           # gemma2: post-attn/post-mlp RMSNorms
    embed_scale: bool = False          # gemma2: scale embeddings by sqrt(d_model)

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.pad_q_heads_to or self.pad_kv_heads_to:
            hq = self.pad_q_heads_to or self.n_heads
            hkv = self.pad_kv_heads_to or self.n_kv_heads
            if hq % hkv:
                raise ValueError("padded q heads must be multiple of kv")
            g = hq // hkv
            # real q heads must only read REAL kv heads
            if (self.n_heads - 1) // g >= self.n_kv_heads:
                raise ValueError("padding maps real q heads to padded kv")
        if self.n_experts and not self.experts_per_token:
            raise ValueError("MoE needs experts_per_token")
        if self.family in ("ssm", "hybrid") and not self.ssm_state:
            raise ValueError("SSM family needs ssm_state > 0")

    # -- derived sizes ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_q_heads(self) -> int:
        return self.pad_q_heads_to or self.n_heads

    @property
    def padded_kv_heads(self) -> int:
        return self.pad_kv_heads_to or self.n_kv_heads

    @property
    def heads_padded(self) -> bool:
        return (self.padded_q_heads != self.n_heads
                or self.padded_kv_heads != self.n_kv_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def resolved_shared_d_ff(self) -> int:
        return self.shared_d_ff or self.resolved_moe_d_ff

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def uses_moe(self) -> bool:
        return self.n_experts > 0

    def layer_is_local(self, layer: int) -> bool:
        """gemma2 pattern: even layers local (sliding window), odd global."""
        return self.local_global and layer % 2 == 0

    def window_for_layer(self, layer: int) -> int:
        """Effective attention window for ``layer`` (0 = full causal)."""
        if self.local_global:
            return self.local_window if self.layer_is_local(layer) else 0
        return self.sliding_window

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does NOT grow with context without bound:
        SSM/hybrid (constant state) or SWA on every layer (window-clipped)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            # shared attn layers still attend globally unless windowed
            return self.sliding_window > 0 or self.hybrid_attn_every == 0 or True
        return self.sliding_window > 0 and not self.local_global

    # -- parameter counting (for 6ND roofline + powermodel) --------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per_layer = 0
        attn_layers = 0
        mamba_layers = 0
        total = 0

        def attn_params() -> int:
            if self.use_mla:
                q_in = self.q_lora_rank or d
                p = 0
                if self.q_lora_rank:
                    p += d * self.q_lora_rank
                p += q_in * n_q * (self.nope_head_dim + self.rope_head_dim)
                p += d * (self.kv_lora_rank + self.rope_head_dim)
                p += self.kv_lora_rank * n_q * (self.nope_head_dim + self.v_head_dim)
                p += n_q * self.v_head_dim * d
                return p
            return d * n_q * h + 2 * d * n_kv * h + n_q * h * d

        def mlp_params(hidden: int) -> int:
            per = 3 if self.gated_mlp else 2   # (gate,) up, down
            return per * d * hidden

        def moe_params() -> int:
            p = d * self.n_experts                      # router
            p += self.n_experts * mlp_params(self.resolved_moe_d_ff)
            p += self.n_shared_experts * mlp_params(self.resolved_shared_d_ff)
            return p

        def mamba_params() -> int:
            di, nh, ns = self.d_inner, self.resolved_ssm_heads, self.ssm_state
            g = self.ssm_groups
            p = d * (2 * di + 2 * g * ns + nh)          # in_proj: z, x, B, C, dt
            p += self.conv_width * (di + 2 * g * ns)    # conv over x, B, C
            p += 2 * nh + di                            # A_log, D, gated-norm scale
            p += di * d                                 # out_proj
            return p

        for layer in range(self.n_layers):
            if self.family == "ssm":
                total += mamba_params()
                mamba_layers += 1
                continue
            if self.family == "hybrid":
                total += mamba_params()
                mamba_layers += 1
                continue
            # transformer families
            total += attn_params()
            if self.uses_moe and layer >= self.first_dense_layers:
                total += moe_params()
            else:
                total += mlp_params(self.d_ff)
            total += 2 * d                               # pre-norms
            if self.post_norms:
                total += 2 * d
            attn_layers += 1

        if self.family == "hybrid" and self.hybrid_attn_every:
            # one SHARED attention+MLP block (params counted once)
            total += attn_params() + mlp_params(self.d_ff) + 2 * d
            total += 2 * d * d                           # fused-input projections

        total += d                                       # final norm
        n_emb_vocab = self.vocab_size * d
        if self.n_codebooks:
            total += self.n_codebooks * n_emb_vocab      # per-codebook embeds
            total += self.n_codebooks * n_emb_vocab      # per-codebook heads
        else:
            total += n_emb_vocab
            if not self.tie_embeddings:
                total += n_emb_vocab
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.uses_moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = self.n_layers - self.first_dense_layers
        inactive = (self.n_experts - self.experts_per_token)
        per_expert = 3 * self.d_model * self.resolved_moe_d_ff
        return int(full - moe_layers * inactive * per_expert)
