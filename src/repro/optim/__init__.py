"""Sharded optimizers: AdamW / Adam / SGD-momentum + LR schedules + clipping.

Optimizer state mirrors the param tree leaf-for-leaf, so the param
NamedShardings apply verbatim to the moments — FSDP shards optimizer state
for free (ZeRO-1/2 equivalent under pjit).
"""
from repro.optim.adamw import (OptimizerConfig, adamw_init, adamw_update,
                               global_norm, make_schedule)

__all__ = ["OptimizerConfig", "adamw_init", "adamw_update", "global_norm",
           "make_schedule"]
