"""AdamW (decoupled weight decay) with optional global-norm clipping.

Dependency-free (no optax in this environment).  The update is fully
jit/pjit-compatible; moments are stored in ``moment_dtype`` (fp32 default;
bf16 halves optimizer HBM when the memory roofline term dominates — a
documented hillclimb lever).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"            # adamw | adam | sgd
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0         # 0 = no clipping
    momentum: float = 0.9          # sgd
    moment_dtype: str = "float32"
    # schedule
    schedule: str = "cosine"       # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def make_schedule(cfg: OptimizerConfig):
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
        if cfg.schedule == "constant":
            decay = 1.0
        else:
            t = jnp.clip((step - cfg.warmup_steps)
                         / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
            if cfg.schedule == "cosine":
                decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
                    * 0.5 * (1 + jnp.cos(jnp.pi * t))
            else:                     # linear
                decay = 1.0 - (1.0 - cfg.min_lr_ratio) * t
        return cfg.learning_rate * warm * decay
    return lr_at


def adamw_init(params: Any, cfg: OptimizerConfig) -> dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros_like = lambda p: jnp.zeros(p.shape, mdt)
    state: dict[str, Any] = {"count": jnp.zeros((), jnp.int32)}
    if cfg.kind in ("adamw", "adam"):
        state["mu"] = jax.tree.map(zeros_like, params)
        state["nu"] = jax.tree.map(zeros_like, params)
    elif cfg.kind == "sgd":
        state["mu"] = jax.tree.map(zeros_like, params)
    else:
        raise ValueError(f"unknown optimizer {cfg.kind!r}")
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, state: dict[str, Any], params: Any,
                 cfg: OptimizerConfig):
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = make_schedule(cfg)(count)
    gnorm = global_norm(grads)
    if cfg.clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mdt = jnp.dtype(cfg.moment_dtype)
    if cfg.kind in ("adamw", "adam"):
        b1, b2 = cfg.b1, cfg.b2
        mu = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32))
                          .astype(mdt), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(
                                            g.astype(jnp.float32)))
                          .astype(mdt), state["nu"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m.astype(jnp.float32) / c1
            vhat = v.astype(jnp.float32) / c2
            step = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.kind == "adamw" and p.ndim >= 2:   # no decay on norms/bias
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = {"count": count, "mu": mu, "nu": nu}
    else:                              # sgd + momentum
        mu = jax.tree.map(lambda m, g: (cfg.momentum * m.astype(jnp.float32)
                                        + g.astype(jnp.float32)).astype(mdt),
                          state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * m.astype(jnp.float32)).astype(p.dtype),
            params, mu)
        new_state = {"count": count, "mu": mu}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def opt_state_sharding(param_sharding: Any, state: dict[str, Any],
                       mesh) -> dict[str, Any]:
    """Optimizer-state shardings mirror the params; count replicated."""
    from jax.sharding import NamedSharding, PartitionSpec
    out: dict[str, Any] = {}
    for k, v in state.items():
        if k == "count":
            out[k] = NamedSharding(mesh, PartitionSpec())
        else:
            out[k] = param_sharding
    return out
