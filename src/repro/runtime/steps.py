"""Step builders: train_step (grad-accum microbatching + AdamW), prefill_step,
serve_step (one decode token), decode_loop (a whole multi-token block in one
lax.scan).  These are the functions the launcher jits with in/out shardings
and the dry-run lowers.

Overlap strategy: gradients are accumulated over ``n_micro`` microbatches
inside a lax.scan; the cross-replica psum XLA inserts for the DP axes then
happens ONCE on the accumulated grads (deferred-psum), and the XLA
latency-hiding scheduler can overlap the per-layer FSDP all-gathers of
microbatch i+1 with the compute of microbatch i.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.ops import KernelPolicy
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.layers import NO_MESH
from repro.models.transformer import RunCtx
from repro.optim import OptimizerConfig, adamw_init, adamw_update
from repro.runtime.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Runtime knobs orthogonal to the model config."""
    n_micro: int = 1                   # grad-accumulation microbatches
    remat: str = "dots"                # none | dots | full
    kernel_policy: KernelPolicy = KernelPolicy()
    optimizer: OptimizerConfig = OptimizerConfig()
    sequence_shard: bool = False
    moe_strategy: str = "gather"       # gather | a2a (see models.layers)


def make_run_ctx(cfg: ModelConfig, rules: ShardingRules | None,
                 step_cfg: StepConfig) -> RunCtx:
    if rules is None:
        return RunCtx(parallel=NO_MESH, kernel_policy=step_cfg.kernel_policy,
                      constrain=None, remat=step_cfg.remat)
    return RunCtx(parallel=rules.parallel_ctx(),
                  kernel_policy=step_cfg.kernel_policy,
                  constrain=rules.constrain, remat=step_cfg.remat)


def init_train_state(key, cfg: ModelConfig, step_cfg: StepConfig):
    """(params, axes) + optimizer state, as one state dict."""
    params, axes = tfm.init_lm(key, cfg)
    opt = adamw_init(params, step_cfg.optimizer)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}, axes


def train_state_sharding(rules: ShardingRules, axes_tree) -> dict[str, Any]:
    from jax.sharding import NamedSharding, PartitionSpec
    psh = rules.param_sharding(axes_tree)
    rep = NamedSharding(rules.mesh, PartitionSpec())
    opt_cfg_placeholder = {"count": rep, "mu": psh, "nu": psh}
    return {"params": psh, "opt": opt_cfg_placeholder, "step": rep}


def make_train_step(cfg: ModelConfig, step_cfg: StepConfig,
                    rules: ShardingRules | None = None) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    batch = {"inputs": (B, S) [or (B,S,n_cb)], "targets": same,
             optional "image_embeds": (B, n_img, d)}.
    """
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def loss_fn(params, inputs, targets, extra):
        return tfm.lm_loss_pre_shifted(params, inputs, targets, cfg, ctx,
                                       extra_embeds=extra)

    def train_step(state, batch):
        params = state["params"]
        n_micro = step_cfg.n_micro
        inputs, targets = batch["inputs"], batch["targets"]
        extra = batch.get("image_embeds")

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, inputs,
                                                      targets, extra)
        else:
            B = inputs.shape[0]
            mb = B // n_micro

            def resh(x):
                return x.reshape((n_micro, mb) + x.shape[1:])

            micro_batches = (resh(inputs), resh(targets),
                             resh(extra) if extra is not None else None)

            def micro(carry, xs):
                gsum, lsum = carry
                mi, mt, me = xs
                l, g = jax.value_and_grad(loss_fn)(params, mi, mt, me)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), micro_batches)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro

        new_params, new_opt, om = adamw_update(grads, state["opt"], params,
                                               step_cfg.optimizer)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, step_cfg: StepConfig,
                      rules: ShardingRules | None = None,
                      max_len: int = 0) -> Callable:
    """prefill(params, batch) -> (last_logits, cache)."""
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def prefill_step(params, batch):
        logits, cache = tfm.prefill(params, batch["inputs"], cfg, ctx,
                                    max_len=max_len,
                                    extra_embeds=batch.get("image_embeds"))
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, step_cfg: StepConfig,
                    rules: ShardingRules | None = None,
                    greedy: bool = True) -> Callable:
    """serve(params, cache, tokens) -> (next_token_or_logits, cache).

    One new token per sequence against the ring-buffer cache — this is the
    graph the decode_32k / long_500k cells lower.
    """
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def serve_step(params, cache, tokens):
        logits, cache = tfm.decode_step(params, cache, tokens, cfg, ctx)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache
        return logits, cache

    return serve_step


def _decode_loop_impl(params, cache, tokens, active, key, *, cfg, ctx,
                      n_tokens, greedy, temperature):
    """Shared fused-loop body: ``n_tokens`` decode steps (model forward,
    sampling, cache update) in ONE ``lax.scan``.  ``active`` is None for
    the ring layout; for the paged layout it gates the per-slot position
    advance (see ``make_paged_decode_loop``)."""
    if greedy:
        keys = None                            # no PRNG work on the hot path
    else:
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, n_tokens)

    def body(carry, key_t):
        cache, tok = carry
        logits, cache = tfm.decode_step(params, cache, tok, cfg, ctx,
                                        active=active)
        last = logits[:, -1]                   # (B, V) or (B, n_cb, V)
        if greedy:
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key_t, last / temperature, axis=-1)
        nxt = nxt.astype(jnp.int32)
        return (cache, nxt[:, None]), nxt

    (cache, _), toks = jax.lax.scan(body, (cache, tokens), keys,
                                    length=n_tokens)
    return jnp.moveaxis(toks, 0, 1), cache     # (B, n_tokens[, n_cb])


def make_decode_loop(cfg: ModelConfig, step_cfg: StepConfig,
                     rules: ShardingRules | None = None,
                     n_tokens: int = 16, *, greedy: bool = True,
                     temperature: float = 1.0) -> Callable:
    """decode_loop(params, cache, tokens, key=None) -> (token_block, cache).

    Runs ``n_tokens`` decode steps (sampling + cache update) inside ONE
    jitted ``lax.scan`` — no host round-trip per token, which is what makes
    the serving loop dispatch-free (benchmarks/decode_throughput.py measures
    the gap vs the per-token ``make_serve_step`` host loop).  ``tokens`` is
    the (B, 1) [or (B, 1, n_cb)] token that *enters* the model first; the
    returned block (B, n_tokens[, n_cb]) holds the tokens sampled after it.
    Jit with ``donate_argnums`` on the cache so the ring buffers update in
    place across chunks.
    """
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def decode_loop(params, cache, tokens, key=None):
        return _decode_loop_impl(params, cache, tokens, None, key, cfg=cfg,
                                 ctx=ctx, n_tokens=n_tokens, greedy=greedy,
                                 temperature=temperature)

    return decode_loop


def make_paged_decode_loop(cfg: ModelConfig, step_cfg: StepConfig,
                           rules: ShardingRules | None = None,
                           n_tokens: int = 16, *, greedy: bool = True,
                           temperature: float = 1.0) -> Callable:
    """decode_loop(params, cache, tokens, active, key=None)
    -> (token_block, cache) over the *paged* cache layout.

    The continuous-batching engine's inner loop: ``cache`` comes from
    ``transformer.init_paged_cache`` (per-slot positions + block tables +
    shared page pools) and ``active`` (B,) marks which slots hold a live
    request.  Every slot decodes every step — the grid is fixed so ONE
    executable serves all occupancy patterns — but only active slots
    advance their position; parked slots spin on their scratch page and
    their tokens are discarded by the engine at harvest.  Jit with
    ``donate_argnums`` on the cache so the pools update in place."""
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def decode_loop(params, cache, tokens, active, key=None):
        return _decode_loop_impl(params, cache, tokens,
                                 jnp.asarray(active, jnp.int32), key,
                                 cfg=cfg, ctx=ctx, n_tokens=n_tokens,
                                 greedy=greedy, temperature=temperature)

    return decode_loop
