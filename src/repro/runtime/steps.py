"""Step builders: train_step (grad-accum microbatching + AdamW), prefill_step,
serve_step (one decode token), decode_loop (a whole multi-token block in one
lax.scan), and the speculative loops (K+1-token verify sweeps with in-scan
draft -> accept -> commit).  These are the functions the launcher jits with
in/out shardings and the dry-run lowers.

Overlap strategy: gradients are accumulated over ``n_micro`` microbatches
inside a lax.scan; the cross-replica psum XLA inserts for the DP axes then
happens ONCE on the accumulated grads (deferred-psum), and the XLA
latency-hiding scheduler can overlap the per-layer FSDP all-gathers of
microbatch i+1 with the compute of microbatch i.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.ops import KernelPolicy
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.layers import NO_MESH
from repro.models.transformer import RunCtx
from repro.optim import OptimizerConfig, adamw_init, adamw_update
from repro.runtime.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Runtime knobs orthogonal to the model config."""
    n_micro: int = 1                   # grad-accumulation microbatches
    remat: str = "dots"                # none | dots | full
    kernel_policy: KernelPolicy = KernelPolicy()
    optimizer: OptimizerConfig = OptimizerConfig()
    sequence_shard: bool = False
    moe_strategy: str = "gather"       # gather | a2a (see models.layers)


def with_decode_policy(step_cfg: StepConfig, *,
                       kv_splits: str | int | None = None,
                       decode_k_chunk: int | None = None,
                       kv_dtype: str | None = None) -> StepConfig:
    """Return ``step_cfg`` with decode-sweep knobs swapped on its
    ``KernelPolicy`` (both dataclasses are frozen, hence the replace
    dance).  ``None`` leaves a knob at its current value — callers thread
    CLI/engine config through without caring which knobs were set."""
    repl: dict[str, Any] = {}
    if kv_splits is not None:
        repl["kv_splits"] = kv_splits
    if decode_k_chunk is not None:
        repl["decode_k_chunk"] = int(decode_k_chunk)
    if kv_dtype is not None:
        repl["kv_dtype"] = str(kv_dtype)
    if not repl:
        return step_cfg
    policy = dataclasses.replace(step_cfg.kernel_policy, **repl)
    return dataclasses.replace(step_cfg, kernel_policy=policy)


def make_run_ctx(cfg: ModelConfig, rules: ShardingRules | None,
                 step_cfg: StepConfig) -> RunCtx:
    if rules is None:
        return RunCtx(parallel=NO_MESH, kernel_policy=step_cfg.kernel_policy,
                      constrain=None, remat=step_cfg.remat)
    return RunCtx(parallel=rules.parallel_ctx(),
                  kernel_policy=step_cfg.kernel_policy,
                  constrain=rules.constrain, remat=step_cfg.remat)


def init_train_state(key, cfg: ModelConfig, step_cfg: StepConfig):
    """(params, axes) + optimizer state, as one state dict."""
    params, axes = tfm.init_lm(key, cfg)
    opt = adamw_init(params, step_cfg.optimizer)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}, axes


def train_state_sharding(rules: ShardingRules, axes_tree) -> dict[str, Any]:
    from jax.sharding import NamedSharding, PartitionSpec
    psh = rules.param_sharding(axes_tree)
    rep = NamedSharding(rules.mesh, PartitionSpec())
    opt_cfg_placeholder = {"count": rep, "mu": psh, "nu": psh}
    return {"params": psh, "opt": opt_cfg_placeholder, "step": rep}


def make_train_step(cfg: ModelConfig, step_cfg: StepConfig,
                    rules: ShardingRules | None = None) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    batch = {"inputs": (B, S) [or (B,S,n_cb)], "targets": same,
             optional "image_embeds": (B, n_img, d)}.
    """
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def loss_fn(params, inputs, targets, extra):
        return tfm.lm_loss_pre_shifted(params, inputs, targets, cfg, ctx,
                                       extra_embeds=extra)

    def train_step(state, batch):
        params = state["params"]
        n_micro = step_cfg.n_micro
        inputs, targets = batch["inputs"], batch["targets"]
        extra = batch.get("image_embeds")

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, inputs,
                                                      targets, extra)
        else:
            B = inputs.shape[0]
            mb = B // n_micro

            def resh(x):
                return x.reshape((n_micro, mb) + x.shape[1:])

            micro_batches = (resh(inputs), resh(targets),
                             resh(extra) if extra is not None else None)

            def micro(carry, xs):
                gsum, lsum = carry
                mi, mt, me = xs
                l, g = jax.value_and_grad(loss_fn)(params, mi, mt, me)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), micro_batches)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro

        new_params, new_opt, om = adamw_update(grads, state["opt"], params,
                                               step_cfg.optimizer)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, step_cfg: StepConfig,
                      rules: ShardingRules | None = None,
                      max_len: int = 0) -> Callable:
    """prefill(params, batch) -> (last_logits, cache)."""
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def prefill_step(params, batch):
        logits, cache = tfm.prefill(params, batch["inputs"], cfg, ctx,
                                    max_len=max_len,
                                    extra_embeds=batch.get("image_embeds"))
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, step_cfg: StepConfig,
                    rules: ShardingRules | None = None,
                    greedy: bool = True) -> Callable:
    """serve(params, cache, tokens) -> (next_token_or_logits, cache).

    One new token per sequence against the ring-buffer cache — this is the
    graph the decode_32k / long_500k cells lower.
    """
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def serve_step(params, cache, tokens):
        logits, cache = tfm.decode_step(params, cache, tokens, cfg, ctx)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache
        return logits, cache

    return serve_step


def _decode_loop_impl(params, cache, tokens, active, key, *, cfg, ctx,
                      n_tokens, greedy, temperature):
    """Shared fused-loop body: ``n_tokens`` decode steps (model forward,
    sampling, cache update) in ONE ``lax.scan``.  ``active`` is None for
    the ring layout; for the paged layout it gates the per-slot position
    advance (see ``make_paged_decode_loop``)."""
    if greedy:
        keys = None                            # no PRNG work on the hot path
    else:
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, n_tokens)

    def body(carry, key_t):
        cache, tok = carry
        logits, cache = tfm.decode_step(params, cache, tok, cfg, ctx,
                                        active=active)
        last = logits[:, -1]                   # (B, V) or (B, n_cb, V)
        if greedy:
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key_t, last / temperature, axis=-1)
        nxt = nxt.astype(jnp.int32)
        return (cache, nxt[:, None]), nxt

    (cache, _), toks = jax.lax.scan(body, (cache, tokens), keys,
                                    length=n_tokens)
    return jnp.moveaxis(toks, 0, 1), cache     # (B, n_tokens[, n_cb])


def make_decode_loop(cfg: ModelConfig, step_cfg: StepConfig,
                     rules: ShardingRules | None = None,
                     n_tokens: int = 16, *, greedy: bool = True,
                     temperature: float = 1.0) -> Callable:
    """decode_loop(params, cache, tokens, key=None) -> (token_block, cache).

    Runs ``n_tokens`` decode steps (sampling + cache update) inside ONE
    jitted ``lax.scan`` — no host round-trip per token, which is what makes
    the serving loop dispatch-free (benchmarks/decode_throughput.py measures
    the gap vs the per-token ``make_serve_step`` host loop).  ``tokens`` is
    the (B, 1) [or (B, 1, n_cb)] token that *enters* the model first; the
    returned block (B, n_tokens[, n_cb]) holds the tokens sampled after it.
    Jit with ``donate_argnums`` on the cache so the ring buffers update in
    place across chunks.
    """
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def decode_loop(params, cache, tokens, key=None):
        return _decode_loop_impl(params, cache, tokens, None, key, cfg=cfg,
                                 ctx=ctx, n_tokens=n_tokens, greedy=greedy,
                                 temperature=temperature)

    return decode_loop


def make_prefill_suffix_step(cfg: ModelConfig, step_cfg: StepConfig,
                             rules: ShardingRules | None = None) -> Callable:
    """suffix_step(params, cache, tokens, n_commit) -> (logits, cache).

    One chunked-paged-prefill sweep (see ``transformer.prefill_suffix``):
    ``tokens`` is (n_slots, chunk) with the joining slot's row holding the
    next ``n_commit[slot]`` uncached prompt-suffix tokens (other rows are
    pad, ``n_commit == 0``).  The chunk size is whatever width the caller
    traces with — a fixed shape means ONE AOT executable covers every
    suffix length (the engine loops it and pads the tail).  Jit with
    ``donate_argnums=(1,)`` so the page pools update in place."""
    ctx = make_run_ctx(cfg, rules, step_cfg)
    blockers = tfm.chunked_prefill_blockers(cfg)
    if blockers:
        raise ValueError(f"{cfg.name}: chunked paged prefill blocked by "
                         f"{blockers[0]}")

    def suffix_step(params, cache, tokens, n_commit):
        return tfm.prefill_suffix(params, cache, tokens,
                                  jnp.asarray(n_commit, jnp.int32), cfg, ctx)

    return suffix_step


def _spec_accept_greedy(logits, drafts):
    """Greedy exact-match acceptance: per-row accepted-draft counts.

    logits: (B, Q, V) for the fed block [t_last, d_1..d_K]; row i scores
    the token AFTER position pos+i.  Draft d_{i+1} is accepted iff it
    equals argmax(row i) AND every earlier draft was accepted — the
    emitted block is then argmax rows 0..a (accepted drafts + the free
    "bonus" token), which is exactly the plain greedy stream."""
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # (B, Q)
    match = (drafts == g[:, :-1]).astype(jnp.int32)          # (B, K)
    acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)        # (B,)
    return g, acc


def _spec_accept_sample(logits, drafts, acc_flags, a_vec, key, temperature):
    """Temperature rejection-sampling acceptance for point-mass (deterministic)
    drafters, per Leviathan et al.: accept d_{i+1} with probability
    p(d_{i+1}); at the first rejection resample from the residual
    max(0, p - q) (= p with the rejected draft's mass removed); when all K
    drafts survive, sample the bonus token from the last row.  Returns the
    emitted block with the correction/bonus token spliced in at ``a_vec``.

    The target distribution is preserved exactly — rejected drafts cost
    compute (charged as overhead in J/accepted-token) but never bias the
    stream."""
    B, Q, V = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
    p_base = jnp.take_along_axis(
        probs, a_vec[:, None, None], axis=1)[:, 0]           # (B, V) row a
    d_pad = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)  # (B, Q)
    d_at = jnp.take_along_axis(d_pad, a_vec[:, None], axis=1)[:, 0]
    is_bonus = a_vec == Q - 1
    # was row a_vec's draft itself accepted?  (ring lockstep can truncate a
    # row below its own acceptance count — the accepted draft IS a valid
    # sample from p and must be emitted, not resampled)
    f_pad = jnp.concatenate(
        [acc_flags, jnp.zeros((B, 1), acc_flags.dtype)], axis=1)
    accepted_here = jnp.take_along_axis(
        f_pad, a_vec[:, None], axis=1)[:, 0] > 0
    onehot = jax.nn.one_hot(d_at, V, dtype=probs.dtype)
    dist = jnp.where(is_bonus[:, None], p_base, p_base * (1.0 - onehot))
    samp = jax.random.categorical(key, jnp.log(dist + 1e-30), axis=-1)
    last_tok = jnp.where(is_bonus | ~accepted_here, samp,
                         d_at).astype(jnp.int32)
    emit = jnp.where(jnp.arange(Q)[None, :] == a_vec[:, None],
                     last_tok[:, None], d_pad)
    return emit


def _spec_loop_impl(params, cache, tokens, active, dstate, key, *, cfg, ctx,
                    drafter, n_steps, greedy, temperature, per_slot):
    """Shared speculative-loop body: ``n_steps`` x (draft -> verify ->
    accept -> commit) entirely inside ONE ``lax.scan``.  ``per_slot`` keeps
    per-row accepted counts (paged layout: every slot sits at its own
    depth); the ring layout's scalar ``pos`` forces the batch to advance in
    lockstep, so acceptance truncates to the batch minimum — still exact,
    just conservative (B=1 serving pays nothing)."""
    K = drafter.spec_k
    Q = K + 1
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, n_steps)    # cheap; unused rows DCE'd when greedy

    def body(carry, key_t):
        cache, tok, dstate = carry
        drafts = drafter.propose(dstate, tok[:, 0])          # (B, K)
        block = jnp.concatenate([tok, drafts], axis=1)       # (B, Q)
        logits, pending = tfm.verify_step(params, cache, block, cfg, ctx)
        if greedy:
            g, acc = _spec_accept_greedy(logits, drafts)
        else:
            probs = jax.nn.softmax(
                logits.astype(jnp.float32) / temperature, axis=-1)
            p_draft = jnp.take_along_axis(
                probs[:, :-1], drafts[..., None], axis=-1)[..., 0]  # (B, K)
            k_acc, k_emit = jax.random.split(key_t)
            u = jax.random.uniform(k_acc, p_draft.shape)
            ok = (u < p_draft).astype(jnp.int32)
            acc = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)   # (B,)
        if per_slot:
            a_vec = acc
        else:
            a_vec = jnp.broadcast_to(jnp.min(acc), acc.shape)
        if greedy:
            emit = g
        else:
            emit = _spec_accept_sample(logits, drafts, ok, a_vec, k_emit,
                                       temperature)
        counts = a_vec + 1
        if per_slot:
            counts = jnp.where(active > 0, counts, 0)
            cache = tfm.commit_spec_paged(cache, pending, a_vec, active, cfg)
        else:
            cache = tfm.commit_spec(cache, pending, a_vec[0], cfg)
        dstate = drafter.observe(dstate, emit, counts)
        tok_next = jnp.take_along_axis(emit, a_vec[:, None], axis=1)
        return (cache, tok_next, dstate), (emit, counts)

    (cache, _, dstate), (toks, counts) = jax.lax.scan(
        body, (cache, tokens, dstate), keys, length=n_steps)
    # (n_steps, B, Q) -> (B, n_steps, Q); counts (n_steps, B) -> (B, n_steps)
    return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(counts, 0, 1), cache, dstate


def make_speculative_decode_loop(cfg: ModelConfig, step_cfg: StepConfig,
                                 rules: ShardingRules | None = None,
                                 n_steps: int = 8, *, drafter,
                                 greedy: bool = True,
                                 temperature: float = 1.0) -> Callable:
    """spec_loop(params, cache, tokens, drafter_state, key=None)
    -> (token_blocks (B, n_steps, K+1), counts (B, n_steps), cache, state).

    The fused decode loop's speculative sibling over the ring cache:
    ``n_steps`` verify steps, each scoring K+1 tokens in ONE cache sweep
    (draft -> verify -> accept -> commit, all in-scan, zero host traffic).
    ``counts[:, s]`` is step s's emitted-token count (accepted drafts + 1);
    only the first ``counts`` entries of each block are real — greedy
    emission is bit-identical to ``make_decode_loop``'s stream, just
    delivered up to K+1 tokens per sweep.  The ring's scalar ``pos``
    advances the batch in lockstep (acceptance truncates to the batch
    minimum); the paged variant keeps per-slot counts.  Jit with
    ``donate_argnums`` on the cache, as with the plain loop."""
    ctx = make_run_ctx(cfg, rules, step_cfg)
    blockers = tfm.speculative_blockers(cfg)
    if blockers:
        raise ValueError(f"{cfg.name}: speculative decode blocked by "
                         f"{blockers[0]}")

    def spec_loop(params, cache, tokens, drafter_state, key=None):
        return _spec_loop_impl(params, cache, tokens, None, drafter_state,
                               key, cfg=cfg, ctx=ctx, drafter=drafter,
                               n_steps=n_steps, greedy=greedy,
                               temperature=temperature, per_slot=False)

    return spec_loop


def make_paged_speculative_decode_loop(cfg: ModelConfig, step_cfg: StepConfig,
                                       rules: ShardingRules | None = None,
                                       n_steps: int = 8, *, drafter,
                                       greedy: bool = True,
                                       temperature: float = 1.0) -> Callable:
    """spec_loop(params, cache, tokens, active, drafter_state, key=None)
    -> (token_blocks (B, n_steps, K+1), counts (B, n_steps), cache, state)
    over the *paged* cache layout — the serving engine's speculative inner
    loop.  ``pos`` is per-slot, so every slot keeps its own accepted
    prefix: the engine's harvest consumes a variable number of tokens per
    slot per step.  Parked slots verify scratch garbage (fixed grid, one
    executable) but neither commit nor advance, and their counts are 0."""
    ctx = make_run_ctx(cfg, rules, step_cfg)
    blockers = (tfm.speculative_blockers(cfg)
                or tfm.chunked_prefill_blockers(cfg))
    if blockers:
        raise ValueError(f"{cfg.name}: paged speculative decode blocked by "
                         f"{blockers[0]}")

    def spec_loop(params, cache, tokens, active, drafter_state, key=None):
        return _spec_loop_impl(params, cache, tokens,
                               jnp.asarray(active, jnp.int32), drafter_state,
                               key, cfg=cfg, ctx=ctx, drafter=drafter,
                               n_steps=n_steps, greedy=greedy,
                               temperature=temperature, per_slot=True)

    return spec_loop


def make_paged_decode_loop(cfg: ModelConfig, step_cfg: StepConfig,
                           rules: ShardingRules | None = None,
                           n_tokens: int = 16, *, greedy: bool = True,
                           temperature: float = 1.0) -> Callable:
    """decode_loop(params, cache, tokens, active, key=None)
    -> (token_block, cache) over the *paged* cache layout.

    The continuous-batching engine's inner loop: ``cache`` comes from
    ``transformer.init_paged_cache`` (per-slot positions + block tables +
    shared page pools) and ``active`` (B,) marks which slots hold a live
    request.  Every slot decodes every step — the grid is fixed so ONE
    executable serves all occupancy patterns — but only active slots
    advance their position; parked slots spin on their scratch page and
    their tokens are discarded by the engine at harvest.  Jit with
    ``donate_argnums`` on the cache so the pools update in place."""
    ctx = make_run_ctx(cfg, rules, step_cfg)

    def decode_loop(params, cache, tokens, active, key=None):
        return _decode_loop_impl(params, cache, tokens,
                                 jnp.asarray(active, jnp.int32), key,
                                 cfg=cfg, ctx=ctx, n_tokens=n_tokens,
                                 greedy=greedy, temperature=temperature)

    return decode_loop
