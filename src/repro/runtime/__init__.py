"""Distributed runtime: sharding rules, step builders, speculative
decoding drafters, fault tolerance, gradient compression."""
from repro.runtime.sharding import (ShardingRules, batch_sharding,
                                    build_rules, cache_sharding)
from repro.runtime.speculate import (Drafter, NgramDrafter, RepeatDrafter,
                                     ReplayDrafter, get_drafter)
from repro.runtime.steps import (StepConfig, init_train_state,
                                 make_decode_loop, make_prefill_step,
                                 make_serve_step,
                                 make_speculative_decode_loop,
                                 make_paged_speculative_decode_loop,
                                 make_train_step)

__all__ = ["ShardingRules", "build_rules", "batch_sharding", "cache_sharding",
           "StepConfig", "init_train_state", "make_train_step",
           "make_prefill_step", "make_serve_step", "make_decode_loop",
           "make_speculative_decode_loop", "make_paged_speculative_decode_loop",
           "Drafter", "NgramDrafter", "RepeatDrafter", "ReplayDrafter",
           "get_drafter"]
