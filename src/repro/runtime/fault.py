"""Fault tolerance + elasticity: the host-side supervisor.

At 1000+ nodes, mean-time-between-failures drops below a training day, so
the framework assumes failure is routine, not exceptional:

  * heartbeat monitor — every worker (simulated in-container; process/pod in
    deployment) reports per-step liveness + step latency,
  * checkpoint/restart — atomic resumable checkpoints (repro.checkpoint),
    restore-on-failure with at-most-one-step loss of work,
  * elastic re-mesh — on permanent node loss, the supervisor rebuilds the
    mesh with a smaller DP extent and reshards the restored checkpoint (the
    param shardings are pure functions of (cfg, mesh), so resharding is
    just loading with the new rules),
  * straggler mitigation — per-node step latencies feed the FROST
    power-shift allocator (core/powershift): a thermally-derated node gets
    a *larger* power budget (or its neighbours get capped down to match) —
    the paper's power capping doubling as straggler control.

Everything here is host-side Python orchestration — testable on CPU,
hardware-agnostic by construction (the O-RAN portability argument).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.core.powershift import ClusterNode, allocate_power, detect_stragglers


@dataclasses.dataclass
class WorkerState:
    node_id: str
    last_heartbeat: float = 0.0
    step: int = 0
    step_latency_s: float = 0.0
    alive: bool = True
    derate: float = 1.0            # thermal/silicon derate (1 = healthy)


@dataclasses.dataclass
class SupervisorConfig:
    heartbeat_timeout_s: float = 10.0
    checkpoint_every: int = 50
    straggler_threshold: float = 1.15   # >15% above median step time
    max_restarts: int = 8
    elastic: bool = True                # drop dead DP ranks instead of stalling


class Supervisor:
    """Drives a training loop with failure injection + recovery.

    The ``step_fn(state, batch) -> (state, metrics)`` and checkpoint hooks
    are injected, so the same supervisor drives the in-container simulated
    cluster and a real multi-host launch.
    """

    def __init__(self, cfg: SupervisorConfig, *,
                 save_fn: Callable[[Any, int], None],
                 restore_fn: Callable[[], tuple[Any, int]],
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.clock = clock
        self.workers: dict[str, WorkerState] = {}
        self.restarts = 0
        self.events: list[dict] = []

    # -- worker lifecycle -----------------------------------------------------
    def register(self, node_id: str, derate: float = 1.0):
        self.workers[node_id] = WorkerState(node_id, self.clock(),
                                            derate=derate)

    def heartbeat(self, node_id: str, step: int, latency_s: float):
        w = self.workers[node_id]
        w.last_heartbeat = self.clock()
        w.step = step
        w.step_latency_s = latency_s

    def check_liveness(self) -> list[str]:
        """Returns newly-dead node ids."""
        now = self.clock()
        dead = []
        for w in self.workers.values():
            if w.alive and now - w.last_heartbeat > self.cfg.heartbeat_timeout_s:
                w.alive = False
                dead.append(w.node_id)
                self.events.append({"t": now, "event": "node_dead",
                                    "node": w.node_id})
        return dead

    # -- failure handling -------------------------------------------------------
    def handle_failure(self, dead: list[str]) -> dict:
        """Decide the recovery action for the given dead nodes."""
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            return {"action": "abort", "reason": "restart budget exhausted"}
        alive = [w for w in self.workers.values() if w.alive]
        if self.cfg.elastic and alive:
            # shrink the DP extent to the largest power of two that fits
            new_dp = 1 << (len(alive).bit_length() - 1)
            return {"action": "remesh", "new_dp": new_dp,
                    "restore_step": self.restore_fn()[1]}
        return {"action": "restart", "restore_step": self.restore_fn()[1]}

    # -- stragglers -----------------------------------------------------------
    def straggler_report(self):
        nodes = [w.node_id for w in self.workers.values()
                 if w.alive and w.step_latency_s]
        lat = [self.workers[n].step_latency_s for n in nodes]
        if len(lat) < 2:
            return [], {}
        idx = detect_stragglers(lat, threshold=self.cfg.straggler_threshold)
        return [nodes[i] for i in idx], dict(zip(nodes, lat))

    def rebalance_power(self, nodes: list[ClusterNode], budget_w: float):
        """FROST-as-straggler-mitigation: re-split the global power budget
        so derated nodes stop dragging the DP step time."""
        plan = allocate_power(nodes, budget_w)
        self.events.append({"t": self.clock(), "event": "power_rebalance",
                            "plan": {a.node_id: a.cap for a in plan.allocations}})
        return plan

    # -- main loop ------------------------------------------------------------
    def run(self, step_fn, state, batches, *, start_step: int = 0,
            inject_failure_at: dict[int, str] | None = None) -> tuple[Any, dict]:
        """Run to completion with checkpoint/restart.

        ``inject_failure_at``: {step: node_id} — marks the node dead at that
        step (tests + chaos drills).
        """
        step = start_step
        inject = dict(inject_failure_at or {})
        history = []
        it = iter(batches)
        while True:
            try:
                batch = next(it)
            except StopIteration:
                break
            if step in inject:
                w = self.workers.get(inject.pop(step))   # one-shot fault
                if w:
                    w.alive = False
                    w.last_heartbeat = -1e9
            dead = [w.node_id for w in self.workers.values() if not w.alive]
            if dead:
                decision = self.handle_failure(dead)
                self.events.append({"t": self.clock(), "event": "recovery",
                                    **decision})
                if decision["action"] == "abort":
                    break
                state, step = self.restore_fn()
                for d in dead:                      # node replaced / dropped
                    self.workers[d].alive = True
                    self.workers[d].last_heartbeat = self.clock()
                continue
            t0 = self.clock()
            state, metrics = step_fn(state, batch)
            latency = self.clock() - t0
            for w in self.workers.values():
                self.heartbeat(w.node_id, step, latency / max(w.derate, 1e-3))
            step += 1
            history.append({"step": step, **{k: float(v)
                                             for k, v in metrics.items()}})
            if step % self.cfg.checkpoint_every == 0:
                self.save_fn(state, step)
        return state, {"history": history, "events": self.events,
                       "final_step": step, "restarts": self.restarts}
