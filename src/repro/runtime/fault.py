"""Fault tolerance + elasticity: the host-side supervisor.

At 1000+ nodes, mean-time-between-failures drops below a training day, so
the framework assumes failure is routine, not exceptional:

  * heartbeat monitor — every worker (simulated in-container; process/pod in
    deployment) reports per-step liveness + step latency,
  * checkpoint/restart — atomic resumable checkpoints (repro.checkpoint),
    restore-on-failure with at-most-one-step loss of work,
  * elastic re-mesh — on permanent node loss, the supervisor rebuilds the
    mesh with a smaller DP extent and reshards the restored checkpoint (the
    param shardings are pure functions of (cfg, mesh), so resharding is
    just loading with the new rules),
  * straggler mitigation — per-node step latencies feed the FROST
    power-shift allocator (core/powershift): a thermally-derated node gets
    a *larger* power budget (or its neighbours get capped down to match) —
    the paper's power capping doubling as straggler control.

Everything here is host-side Python orchestration — testable on CPU,
hardware-agnostic by construction (the O-RAN portability argument).

Serving nodes get the same treatment via :class:`ServingSupervisor`:
``ServeEngine`` chunks emit heartbeats, missed heartbeats drive liveness
(-> preempt/requeue of the dead engine's slots through the restore path),
and chunk-wall inflation is folded into a derate estimate published as
``NodeDerated`` on the control bus — the FROST power-shift loop fed from
serving telemetry (see docs/fault_tolerance.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.control.events import NodeDerated
from repro.core.powershift import ClusterNode, allocate_power, detect_stragglers


@dataclasses.dataclass
class WorkerState:
    node_id: str
    last_heartbeat: float = 0.0
    step: int = 0
    step_latency_s: float = 0.0
    alive: bool = True
    derate: float = 1.0            # thermal/silicon derate (1 = healthy)


@dataclasses.dataclass
class SupervisorConfig:
    heartbeat_timeout_s: float = 10.0
    checkpoint_every: int = 50
    straggler_threshold: float = 1.15   # >15% above median step time
    max_restarts: int = 8
    elastic: bool = True                # drop dead DP ranks instead of stalling


class Supervisor:
    """Drives a training loop with failure injection + recovery.

    The ``step_fn(state, batch) -> (state, metrics)`` and checkpoint hooks
    are injected, so the same supervisor drives the in-container simulated
    cluster and a real multi-host launch.
    """

    def __init__(self, cfg: SupervisorConfig, *,
                 save_fn: Callable[[Any, int], None],
                 restore_fn: Callable[[], tuple[Any, int]],
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.clock = clock
        self.workers: dict[str, WorkerState] = {}
        self.restarts = 0
        self.events: list[dict] = []
        self._restored: tuple[Any, int] | None = None

    # -- worker lifecycle -----------------------------------------------------
    def register(self, node_id: str, derate: float = 1.0):
        self.workers[node_id] = WorkerState(node_id, self.clock(),
                                            derate=derate)

    def heartbeat(self, node_id: str, step: int, latency_s: float):
        w = self.workers.get(node_id)
        if w is None:
            # a worker reporting before registration is a join (elastic
            # scale-up), not a silent KeyError: register it and log the
            # event so the audit trail shows where it appeared
            self.register(node_id)
            w = self.workers[node_id]
            self.events.append({"t": self.clock(), "event": "auto_register",
                                "node": node_id})
        w.last_heartbeat = self.clock()
        w.step = step
        w.step_latency_s = latency_s

    def check_liveness(self) -> list[str]:
        """Returns newly-dead node ids."""
        now = self.clock()
        dead = []
        for w in self.workers.values():
            if w.alive and now - w.last_heartbeat > self.cfg.heartbeat_timeout_s:
                w.alive = False
                dead.append(w.node_id)
                self.events.append({"t": now, "event": "node_dead",
                                    "node": w.node_id})
        return dead

    # -- failure handling -------------------------------------------------------
    def handle_failure(self, dead: list[str]) -> dict:
        """Decide the recovery action for the given dead nodes.  Restores
        the checkpoint exactly ONCE, stashing the state for the caller
        (``run()`` threads it through via ``take_restored`` instead of
        paying a second restore)."""
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            return {"action": "abort", "reason": "restart budget exhausted"}
        state, restore_step = self.restore_fn()
        self._restored = (state, restore_step)
        alive = [w for w in self.workers.values() if w.alive]
        if self.cfg.elastic and alive:
            # shrink the DP extent to the largest power of two that fits
            new_dp = 1 << (len(alive).bit_length() - 1)
            return {"action": "remesh", "new_dp": new_dp,
                    "restore_step": restore_step}
        return {"action": "restart", "restore_step": restore_step}

    def take_restored(self) -> tuple[Any, int]:
        """The (state, step) the last ``handle_failure`` restored; falls
        back to one restore if called without a stashed result (direct
        ``handle_failure`` users that discarded it)."""
        restored, self._restored = self._restored, None
        if restored is None:
            restored = self.restore_fn()
        return restored

    # -- stragglers -----------------------------------------------------------
    def straggler_report(self):
        nodes = [w.node_id for w in self.workers.values()
                 if w.alive and w.step_latency_s]
        lat = [self.workers[n].step_latency_s for n in nodes]
        if len(lat) < 2:
            return [], {}
        idx = detect_stragglers(lat, threshold=self.cfg.straggler_threshold)
        return [nodes[i] for i in idx], dict(zip(nodes, lat))

    def rebalance_power(self, nodes: list[ClusterNode], budget_w: float):
        """FROST-as-straggler-mitigation: re-split the global power budget
        so derated nodes stop dragging the DP step time."""
        plan = allocate_power(nodes, budget_w)
        self.events.append({"t": self.clock(), "event": "power_rebalance",
                            "plan": {a.node_id: a.cap for a in plan.allocations}})
        return plan

    # -- main loop ------------------------------------------------------------
    def run(self, step_fn, state, batches, *, start_step: int = 0,
            inject_failure_at: dict[int, str] | None = None) -> tuple[Any, dict]:
        """Run to completion with checkpoint/restart.

        ``inject_failure_at``: {step: node_id} — marks the node dead at that
        step (tests + chaos drills).
        """
        step = start_step
        inject = dict(inject_failure_at or {})
        history = []
        it = iter(batches)
        while True:
            try:
                batch = next(it)
            except StopIteration:
                break
            if step in inject:
                w = self.workers.get(inject.pop(step))   # one-shot fault
                if w:
                    # the node goes SILENT (stalled heartbeat) — liveness
                    # has to notice, exactly as a real hang would present
                    w.last_heartbeat = -1e9
            dead = self.check_liveness()
            dead += [w.node_id for w in self.workers.values()
                     if not w.alive and w.node_id not in dead]
            if dead:
                decision = self.handle_failure(dead)
                self.events.append({"t": self.clock(), "event": "recovery",
                                    **decision})
                if decision["action"] == "abort":
                    break
                state, step = self.take_restored()   # restored ONCE, above
                for d in dead:                      # node replaced / dropped
                    self.workers[d].alive = True
                    self.workers[d].last_heartbeat = self.clock()
                continue
            t0 = self.clock()
            state, metrics = step_fn(state, batch)
            latency = self.clock() - t0
            for w in self.workers.values():
                self.heartbeat(w.node_id, step, latency / max(w.derate, 1e-3))
            step += 1
            history.append({"step": step, **{k: float(v)
                                             for k, v in metrics.items()}})
            if step % self.cfg.checkpoint_every == 0:
                self.save_fn(state, step)
        return state, {"history": history, "events": self.events,
                       "final_step": step, "restarts": self.restarts}


class ServingSupervisor(Supervisor):
    """Supervisor-for-serving: liveness + derate inference for a
    ``ServeEngine`` node.

    Wiring: pass ``on_heartbeat`` as the engine's heartbeat hook — every
    decode chunk reports ``(clock_step, chunk_wall_s)``.  The first chunks
    calibrate a healthy-wall baseline (or pass ``baseline_wall_s``); after
    that, EWMA-filtered wall inflation becomes a thermal/silicon derate
    estimate, published as :class:`NodeDerated` on the control bus whenever
    it moves by ``publish_delta`` — the serving half of the FROST
    straggler-mitigation loop (``ClusterCoordinator`` folds it into its
    next power rebalance).  The launcher's outer loop calls :meth:`tick`;
    a missed heartbeat window fires ``on_dead(node_id)``, whose handler
    restores the engine and requeues the dead node's in-flight requests
    (``ServeEngine.restore``)."""

    def __init__(self, cfg: SupervisorConfig | None = None, *,
                 node_id: str = "serve-0", bus=None,
                 baseline_wall_s: float | None = None, ewma: float = 0.5,
                 min_derate: float = 0.2, publish_delta: float = 0.05,
                 on_dead: Callable[[str], None] | None = None,
                 save_fn=None, restore_fn=None,
                 clock: Callable[[], float] = time.monotonic):
        super().__init__(cfg or SupervisorConfig(),
                         save_fn=save_fn or (lambda state, step: None),
                         restore_fn=restore_fn or (lambda: (None, 0)),
                         clock=clock)
        self.node_id = node_id
        self.bus = bus
        self.on_dead = on_dead
        self._baseline = baseline_wall_s
        self._ewma = float(ewma)
        self.min_derate = float(min_derate)
        self.publish_delta = float(publish_delta)
        self._wall_ewma: float | None = None
        self._published = 1.0
        self.n_derates_published = 0
        self.register(node_id)

    def on_heartbeat(self, step: int, wall_s: float) -> None:
        """ServeEngine heartbeat hook: records liveness, then turns chunk
        wall inflation into a derate estimate."""
        self.heartbeat(self.node_id, step, wall_s)
        if wall_s <= 0.0:
            return
        self._wall_ewma = wall_s if self._wall_ewma is None \
            else self._ewma * self._wall_ewma + (1 - self._ewma) * wall_s
        if self._baseline is None:
            # first reading calibrates "healthy" — a pre-derated engine
            # should pass an explicit baseline instead
            self._baseline = self._wall_ewma
            return
        derate = min(1.0, max(self.min_derate,
                              self._baseline / self._wall_ewma))
        self.workers[self.node_id].derate = derate
        if self.bus is not None \
                and abs(derate - self._published) >= self.publish_delta:
            self.bus.publish(NodeDerated(node_id=self.node_id,
                                         derate=derate,
                                         source="serving-supervisor"))
            self._published = derate
            self.n_derates_published += 1

    def tick(self) -> list[str]:
        """Periodic liveness sweep (launcher outer loop / tests): newly
        dead nodes fire ``on_dead`` so their slots get requeued."""
        dead = self.check_liveness()
        for node_id in dead:
            if self.on_dead is not None:
                self.on_dead(node_id)
        return dead
