"""Deterministic chaos injection for the serving stack.

FROST's target is an always-on RAN edge: the energy-control loop has to
keep serving through thermal derates, cap emergencies, and node churn —
not just minimise J/token on a clean run.  This module supplies the
*drill sergeant*: a seeded :class:`FaultInjector` that schedules faults on
the engine's decode-step clock, so every chaos run is reproducible and a
failing CI drill replays exactly.

Fault kinds (``FaultEvent.kind``):

  * ``slot_crash``     — one decode slot dies; its request must be
                         preempted/requeued with zero token loss,
  * ``engine_crash``   — the whole engine process dies mid-chunk; recovery
                         restores the last snapshot and replays,
  * ``page_corrupt``   — poison the paged-KV host metadata (refcount
                         inflation / free-list duplicate / stale trie page);
                         ``PagedKVCache.verify_invariants`` must catch and
                         quarantine it,
  * ``bus_drop`` / ``bus_delay`` — telemetry events vanish or arrive late
                         (exercises the bus's retry + dead-letter path),
  * ``stall``          — the engine misses a heartbeat window; the serving
                         supervisor must notice via liveness,
  * ``derate``         — thermal/silicon derate window (``arg`` = derate
                         fraction, ``duration`` = steps),
  * ``emergency_cap``  — site power emergency (``arg`` = cap fraction,
                         ``duration`` = steps); the engine degrades instead
                         of violating the cap.

This module deliberately imports nothing from ``repro.serving`` /
``repro.control`` at module level — the engine imports *us*, and the
injector stays usable from tests and benchmarks without the serving stack.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = ("slot_crash", "engine_crash", "page_corrupt", "bus_drop",
               "bus_delay", "stall", "derate", "emergency_cap")


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault on the decode-step clock."""
    kind: str
    step: int
    duration: int = 0      # steps the condition persists (derate windows)
    arg: float = 0.0       # kind-specific: slot index / derate / cap fraction
    fired: bool = False

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")


class FaultInjector:
    """Seeded fault schedule polled once per engine decode step.

    The injector is *passive*: the engine (or test harness) calls
    :meth:`poll` with its current step and applies whatever comes due.
    Each event fires exactly once — a restored engine re-attaching the
    same injector does not replay already-fired faults (the crash it just
    recovered from must not recur on resume).
    """

    def __init__(self, events=(), *, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.events: list[FaultEvent] = sorted(events, key=lambda e: e.step)
        self.log: list[FaultEvent] = []
        self.n_injected = 0

    # -- construction --------------------------------------------------------
    def schedule(self, kind: str, step: int, *, duration: int = 0,
                 arg: float = 0.0) -> FaultEvent:
        ev = FaultEvent(kind=kind, step=int(step), duration=int(duration),
                        arg=float(arg))
        self.events.append(ev)
        self.events.sort(key=lambda e: e.step)
        return ev

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0) -> "FaultInjector":
        """Parse ``"kind@step[:duration[:arg]]"`` comma-separated — the CLI
        wire format (e.g. ``"engine_crash@40,emergency_cap@10:8:0.5"``)."""
        inj = cls(seed=seed)
        for item in filter(None, (s.strip() for s in spec.split(","))):
            kind, _, rest = item.partition("@")
            if not rest:
                raise ValueError(f"fault spec {item!r}: expected kind@step")
            parts = rest.split(":")
            inj.schedule(kind, int(parts[0]),
                         duration=int(parts[1]) if len(parts) > 1 else 0,
                         arg=float(parts[2]) if len(parts) > 2 else 0.0)
        return inj

    # -- polling -------------------------------------------------------------
    def poll(self, step: int) -> list[FaultEvent]:
        """Faults due at or before ``step`` that have not fired yet; marks
        them fired (one-shot semantics survive engine restore)."""
        due = [e for e in self.events if not e.fired and e.step <= step]
        for e in due:
            e.fired = True
            self.log.append(e)
            self.n_injected += 1
        return due

    def pending(self) -> int:
        return sum(1 for e in self.events if not e.fired)


# -- paged-KV corruption ------------------------------------------------------
def corrupt_paged_kv(kv, rng: np.random.Generator) -> str | None:
    """Inject one detectable host-metadata corruption into a
    ``PagedKVCache`` — the kind a bit-flip / torn write would leave behind.
    Returns a description, or None if the pool state offers no target.

    Only *detectable* corruptions are injected (refcount inflation,
    free-list duplicate, stale trie page pointer): the point is to drill
    ``verify_invariants(repair=True)``, not to silently poison KV content.
    """
    candidates = []
    held = [p for p in range(kv.n_slots, kv.n_pages)
            if kv.refcount[p] > 0 and p not in kv.quarantined]
    if held:
        candidates.append("refcount")
    if kv.free:
        candidates.append("free_dup")
    trie_nodes = [n for n in _trie_nodes(kv) if n.page >= 0]
    if trie_nodes and kv.free:
        candidates.append("stale_trie")
    if not candidates:
        return None
    kind = candidates[int(rng.integers(len(candidates)))]
    if kind == "refcount":
        page = held[int(rng.integers(len(held)))]
        bump = int(rng.integers(1, 4))
        kv.refcount[page] += bump
        return f"refcount: page {page} inflated by {bump}"
    if kind == "free_dup":
        free = list(kv.free)
        page = free[int(rng.integers(len(free)))]
        kv.free.append(page)
        return f"free_dup: page {page} duplicated in free list"
    node = trie_nodes[int(rng.integers(len(trie_nodes)))]
    free = list(kv.free)
    stale = free[int(rng.integers(len(free)))]
    old = node.page
    node.page = stale
    return f"stale_trie: trie node page {old} -> freed page {stale}"


def _trie_nodes(kv):
    out, stack = [], [kv._root]
    while stack:
        node = stack.pop()
        if node is not kv._root:
            out.append(node)
        stack.extend(node.children.values())
    return out


# -- bus fault wrapper --------------------------------------------------------
class ChaosBus:
    """EventBus wrapper that drops or delays the next N published events.

    Models a lossy/laggy telemetry transport in front of the in-process
    bus: dropped events never reach subscribers; delayed events are held
    and delivered (in order) before the next undisturbed publish, or on an
    explicit :meth:`flush`.  Everything else proxies to the inner bus, so
    a ``ChaosBus`` drops into any ``bus=`` parameter.
    """

    def __init__(self, inner):
        self.inner = inner
        self._drop = 0
        self._delay = 0
        self._held: list = []
        self.n_dropped = 0
        self.n_delayed = 0

    def drop_next(self, n: int = 1) -> None:
        self._drop += int(n)

    def delay_next(self, n: int = 1) -> None:
        self._delay += int(n)

    def publish(self, event) -> int:
        if self._drop > 0:
            self._drop -= 1
            self.n_dropped += 1
            return 0
        if self._delay > 0:
            self._delay -= 1
            self.n_delayed += 1
            self._held.append(event)
            return 0
        delivered = self.flush()
        return delivered + self.inner.publish(event)

    def flush(self) -> int:
        """Deliver held (delayed) events in arrival order."""
        delivered = 0
        while self._held:
            delivered += self.inner.publish(self._held.pop(0))
        return delivered

    def __getattr__(self, name):
        return getattr(self.inner, name)
