"""Drafters for speculative decoding on the fused serving loop.

A drafter proposes ``K`` candidate tokens per verify step; the target model
scores all ``K+1`` (carried token + drafts) in ONE KV-cache sweep
(``transformer.verify_step``) and keeps the longest matching prefix.  Decode
is memory-bound — J/token is dominated by bytes moved, not FLOPs — so every
accepted draft amortises a whole cache+weight sweep that the plain loop
would have paid again (PAPER.md Sec IV: the "do more per Watt" lever).

Drafters are *deterministic and host-free*: ``propose``/``observe`` are jax
functions whose state pytree lives in the fused loop's ``lax.scan`` carry,
so speculation adds zero host round-trips.  The interface doubles as the
draft-model hook — a learned drafter plugs in by implementing ``propose``
against its own state (e.g. a distilled model's cache) without touching the
loop.

Built-ins:

  * ``NgramDrafter``  — prompt-lookup / n-gram self-drafting (no second
    model): find the most recent earlier occurrence of the last committed
    token in the request's history and propose the tokens that followed it.
    Strong on the repetitive streams LLM serving actually sees (code, RAG
    quotes, chat boilerplate) and exactly free otherwise.
  * ``RepeatDrafter`` — proposes the last token K times; the degenerate
    baseline (and a rejection-path stress test).
  * ``ReplayDrafter`` — replays a recorded stream; acceptance is 1.0 by
    construction iff verify/commit are exact, which makes it both the CI
    canary and the ideal-acceptance upper bound for K sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class Drafter:
    """Deterministic drafter driving the speculative decode loop.

    State is a pytree of arrays with a leading batch dim: it rides in the
    jitted loop's carry (device side) and the serving engine mirrors it
    host-side per slot (``init_state`` / ``seed_row``), exactly like the
    paged cache's ``pos``/``block_tables``.
    """

    spec_k: int

    # -- host side ----------------------------------------------------------
    def init_state(self, batch: int) -> dict[str, np.ndarray]:
        """Fresh per-batch state (numpy: the engine mutates rows on join)."""
        raise NotImplementedError

    def seed_row(self, state: dict[str, np.ndarray], row: int,
                 tokens) -> None:
        """Fold a token stream (prompt + first sampled token) into one
        row of a host-side state — called by the engine at prefill-on-join
        and to reset a slot on finish."""
        raise NotImplementedError

    def seed_request(self, state: dict[str, np.ndarray], row: int,
                     prompt, first) -> None:
        """Canonical per-request seeding: the request's prompt followed by
        the prefill-sampled first token — what every caller (engine join,
        launcher, benchmarks, tests) must feed ``seed_row`` so the first
        verify step can already look up prompt n-grams."""
        self.seed_row(state, row, np.concatenate(
            [np.asarray(prompt).reshape(-1), np.asarray(first).reshape(-1)]))

    def seed_batch(self, state: dict[str, np.ndarray], prompts,
                   firsts) -> None:
        """``seed_request`` over every row of a fixed batch."""
        for b in range(len(prompts)):
            self.seed_request(state, b, prompts[b], firsts[b])

    # -- device side (jax-traceable) ----------------------------------------
    def propose(self, state, last: jax.Array) -> jax.Array:
        """(B,) last committed token -> (B, K) draft tokens."""
        raise NotImplementedError

    def observe(self, state, block: jax.Array, count: jax.Array):
        """Fold the emitted tokens back into the state.  ``block`` is the
        (B, K+1) emitted block, ``count`` (broadcastable to (B,)) how many
        leading entries are real; returns the updated state."""
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt-lookup self-drafting over a per-request token history ring.

    ``propose`` finds the most recent *earlier* occurrence of the last
    committed token in the history (prompt + everything emitted) and
    proposes the ``K`` tokens that followed it; with no match it degrades
    to repeating the last token.  O(hist_len) compares per step — noise
    next to one transformer sweep."""

    def __init__(self, spec_k: int, hist_len: int = 128):
        if hist_len < spec_k + 2:
            raise ValueError(f"hist_len {hist_len} too small for K={spec_k}")
        self.spec_k = int(spec_k)
        self.hist_len = int(hist_len)

    def init_state(self, batch: int) -> dict[str, np.ndarray]:
        return {"hist": np.full((batch, self.hist_len), -1, np.int32),
                "cnt": np.zeros((batch,), np.int32)}

    def seed_row(self, state, row: int, tokens) -> None:
        H = self.hist_len
        toks = np.asarray(tokens, np.int32).reshape(-1)
        state["hist"][row] = -1
        # token with stream index i lives at slot i % H (ring)
        for i, t in enumerate(toks[-H:] if len(toks) > H else toks):
            base = max(len(toks) - H, 0)
            state["hist"][row, (base + i) % H] = t
        state["cnt"][row] = len(toks)

    def propose(self, state, last: jax.Array) -> jax.Array:
        hist, cnt = state["hist"], state["cnt"]
        H, K = self.hist_len, self.spec_k
        c0 = jnp.remainder(cnt - 1, H)                       # newest slot
        idx = jnp.arange(H)[None, :]
        age = jnp.remainder(c0[:, None] - idx, H)            # 0 = newest
        n_valid = jnp.minimum(cnt, H)[:, None]
        match = (age >= 1) & (age < n_valid) & (hist == last[:, None])
        best = jnp.min(jnp.where(match, age, H + 1), axis=1)  # (B,)
        found = best <= H
        f_age = best[:, None] - 1 - jnp.arange(K)[None, :]   # followers
        f_slot = jnp.remainder(c0[:, None] - f_age, H)
        cand = jnp.take_along_axis(hist, f_slot, axis=1)
        return jnp.where(found[:, None] & (f_age >= 0), cand,
                         last[:, None]).astype(jnp.int32)

    def observe(self, state, block: jax.Array, count: jax.Array):
        hist, cnt = state["hist"], state["cnt"]
        H = self.hist_len
        count = jnp.broadcast_to(jnp.asarray(count, jnp.int32), cnt.shape)
        rows = jnp.arange(hist.shape[0])
        for i in range(block.shape[1]):                      # K+1 is tiny
            slot = jnp.remainder(cnt + i, H)
            cur = hist[rows, slot]
            hist = hist.at[rows, slot].set(
                jnp.where(i < count, block[:, i], cur))
        return {"hist": hist, "cnt": cnt + count}


class RepeatDrafter(Drafter):
    """Proposes the last committed token K times — the degenerate
    self-drafter.  Perfect on constant streams, rejected otherwise; its
    real job is stressing the rejection/rollback path."""

    def __init__(self, spec_k: int):
        self.spec_k = int(spec_k)

    def init_state(self, batch: int) -> dict[str, np.ndarray]:
        return {"_": np.zeros((batch,), np.int32)}           # pytree placeholder

    def seed_row(self, state, row: int, tokens) -> None:
        pass

    def propose(self, state, last: jax.Array) -> jax.Array:
        return jnp.tile(last[:, None], (1, self.spec_k)).astype(jnp.int32)

    def observe(self, state, block, count):
        return state


class ReplayDrafter(Drafter):
    """Replays a pre-recorded token stream as drafts.

    If the stream is the target model's own greedy output, every draft
    matches and acceptance is exactly 1.0 — *provided* verify/commit are
    bit-exact.  Any masking, commit, or rollback bug shows up as acceptance
    < 1.0, which is what the CI benchmark smoke asserts on."""

    def __init__(self, spec_k: int, stream: np.ndarray):
        self.spec_k = int(spec_k)
        self.stream = np.asarray(stream, np.int32)           # (B, L)

    def init_state(self, batch: int) -> dict[str, np.ndarray]:
        if batch != self.stream.shape[0]:
            raise ValueError("replay stream batch mismatch")
        return {"stream": self.stream.copy(),
                "ptr": np.zeros((batch,), np.int32)}

    def seed_row(self, state, row: int, tokens) -> None:
        pass

    def propose(self, state, last: jax.Array) -> jax.Array:
        stream, ptr = state["stream"], state["ptr"]
        L = stream.shape[1]
        idx = ptr[:, None] + jnp.arange(self.spec_k)[None, :]
        cand = jnp.take_along_axis(stream, jnp.minimum(idx, L - 1), axis=1)
        return jnp.where(idx < L, cand, last[:, None]).astype(jnp.int32)

    def observe(self, state, block, count):
        count = jnp.broadcast_to(jnp.asarray(count, jnp.int32),
                                 state["ptr"].shape)
        # the emitted block's first `count` tokens ARE the replayed stream
        # when acceptance is perfect; on divergence the pointer still moves
        # with the committed position so drafts stay aligned to depth
        return {"stream": state["stream"], "ptr": state["ptr"] + count}


def get_drafter(name: str, spec_k: int, *, hist_len: int = 128) -> Drafter:
    """CLI / engine factory for the built-in self-drafters."""
    if name == "ngram":
        return NgramDrafter(spec_k, hist_len=hist_len)
    if name == "repeat":
        return RepeatDrafter(spec_k)
    raise ValueError(f"unknown drafter {name!r} (replay is test-only: "
                     "construct ReplayDrafter with a recorded stream)")
