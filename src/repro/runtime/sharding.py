"""Sharding rules: logical param/activation axes -> mesh axes.

Scheme (FSDP x TP x EP with pure-DP across pods):

  * batch/tokens  -> ("pod", "data")
  * vocab (padded to /256), MLP hidden, expert dim, ssm inner -> "model" (TP/EP)
  * d_model dims of weights -> "data" (FSDP storage; XLA inserts the
    per-layer all-gathers)
  * attention heads -> "model" ONLY when the head count divides the model
    axis (gemma2, stablelm, deepseek, zamba); otherwise heads stay
    replicated and attention runs batch-parallel with FSDP-gathered weights
    (smollm's 9 heads, musicgen's 24, llava's 56).  This is what makes the
    same rule set compile for every assigned arch.
  * nothing is ever sharded over "pod" except the batch: cross-pod traffic
    is exactly one gradient all-reduce per step (DCI links are scarce).

Activation constraints are shape-checked: a dim that does not divide the
mesh axis quietly resolves to replicated instead of failing at lowering —
this is what lets the long_500k (batch=1) cells share the code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ParallelCtx
from repro.models.ssm import conv_dim


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved logical-axis table for one (cfg, mesh) pair."""
    mesh: Mesh
    table: dict[str, str | None]
    batch_axes: tuple[str, ...]
    sequence_shard: bool = False    # SP: shard the seq dim of the residual
                                    # stream over "model" (hillclimb lever)

    # -- params ---------------------------------------------------------------
    def param_spec(self, axes: tuple[str | None, ...]) -> P:
        return P(*[self.table.get(a) if a else None for a in axes])

    def param_sharding(self, axes_tree: Any) -> Any:
        is_axes = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        return jax.tree.map(
            lambda axes: NamedSharding(self.mesh, self.param_spec(axes)),
            axes_tree, is_leaf=is_axes)

    # -- activations ------------------------------------------------------------
    def _axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            return int(np.prod([self.mesh.shape[a] for a in name]))
        return self.mesh.shape[name]

    def act_spec(self, x, logical: tuple) -> P:
        """Shape-checked activation spec.  'batch' -> the DP axes; named
        table entries -> their mesh axis; non-divisible dims -> replicated."""
        spec: list = []
        for dim, name in enumerate(logical):
            if name == "batch":
                axes = tuple(a for a in self.batch_axes
                             if a in self.mesh.shape and self.mesh.shape[a] > 1)
                size = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
                spec.append(axes if (axes and x.shape[dim] % size == 0) else None)
            elif name is None:
                spec.append(None)
            else:
                m = self.table.get(name)
                if m is not None and x.shape[dim] % self.mesh.shape[m] == 0:
                    spec.append(m)
                else:
                    spec.append(None)
        # sequence parallelism: residual stream (batch, seq, embed_act)
        if (self.sequence_shard and len(logical) >= 3
                and logical[0] == "batch" and logical[-1] == "embed_act"
                and spec[1] is None
                and x.shape[1] % self.mesh.shape["model"] == 0):
            spec[1] = "model"
        return P(*spec)

    def constrain(self, x, logical: tuple):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.act_spec(x, logical)))

    moe_strategy: str = "gather"

    def parallel_ctx(self) -> ParallelCtx:
        return ParallelCtx(mesh=self.mesh, batch_axes=self.batch_axes,
                           moe_strategy=self.moe_strategy)


def build_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp_axis: str = "data",
                tp_axis: str = "model", sequence_shard: bool = False,
                fsdp: bool = True, moe_strategy: str = "gather") -> ShardingRules:
    tp = mesh.shape.get(tp_axis, 1)
    dpn = mesh.shape.get(fsdp_axis, 1)
    dp = fsdp_axis if (fsdp and fsdp_axis in mesh.shape) else None

    def tp_if(n: int) -> str | None:
        return tp_axis if (n and n % tp == 0) else None

    if moe_strategy == "a2a" and cfg.n_experts and cfg.n_experts % dpn == 0:
        expert_axes = {"experts": fsdp_axis, "expert_d": None,
                       "expert_ff": tp_if(cfg.resolved_moe_d_ff)}
    else:
        expert_axes = {"experts": tp_if(cfg.n_experts), "expert_d": dp,
                       "expert_ff": None}

    table: dict[str, str | None] = {
        "vocab": tp_axis,                      # padded to /256 upstream
        "embed": dp,                           # FSDP storage shard
        "embed_out": tp_if(cfg.d_model),
        "mlp": tp_axis,
        "mlp_act": tp_axis,
        **expert_axes,
        "layers": None,
        "q_heads": tp_if(cfg.padded_q_heads),
        "kv_heads": tp_if(cfg.padded_kv_heads),
        "embed_act": None,                     # residual stream replicated
        "ssm_inner": tp_if(cfg.d_inner if cfg.uses_ssm else 0),
        "ssm_act": tp_if(cfg.d_inner if cfg.uses_ssm else 0),
        "ssm_heads": tp_if(cfg.resolved_ssm_heads if cfg.uses_ssm else 0),
        "conv_channels": tp_if(conv_dim(cfg) if cfg.uses_ssm else 0),
        "codebooks": None,
    }
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    effective = ("a2a" if "experts" in expert_axes
                 and expert_axes["experts"] == fsdp_axis else "gather")
    return ShardingRules(mesh=mesh, table=table, batch_axes=batch_axes,
                         sequence_shard=sequence_shard,
                         moe_strategy=effective)


# --------------------------------------------------------------------------
# cache shardings (decode)
# --------------------------------------------------------------------------
def cache_sharding(rules: ShardingRules, cache: Any, cfg: ModelConfig) -> Any:
    """NamedShardings for a decode-cache pytree.

    Batch dim shards over the DP axes when divisible; otherwise (long_500k,
    batch=1) the per-head / channel dims shard over "model" so the 500k KV
    slabs split across the TP group.
    """
    mesh = rules.mesh
    baxes = tuple(a for a in rules.batch_axes if mesh.shape[a] > 1)
    bsize = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    tp = mesh.shape.get("model", 1)

    def spec_for(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        names = [p.key for p in path if hasattr(p, "key")]
        stacked = "units" in names or "shared" in names   # leading layer dim
        b_dim = 1 if stacked else 0
        spec: list = [None] * leaf.ndim
        if leaf.shape[b_dim] % bsize == 0 and bsize > 1:
            spec[b_dim] = baxes
        # shard the head/channel/capacity structure over model.  A 32k-deep
        # KV slab per sequence does NOT fit one chip for the big archs, so
        # when heads cannot shard (24/56/9 heads vs 16-way model axis) — or
        # for MLA latents, which have no head dim at all — the ring
        # CAPACITY dim shards instead (flash-decode style partial softmax;
        # XLA SPMD inserts the combine reduce).
        kind = names[-1]
        if kind in ("k", "v"):
            if leaf.shape[-2] % tp == 0:
                spec[-2] = "model"             # kv heads
            elif leaf.shape[b_dim + 1] % tp == 0:
                spec[b_dim + 1] = "model"      # ring capacity
        elif kind == "ssm" and leaf.shape[b_dim + 1] % tp == 0:
            spec[b_dim + 1] = "model"          # ssm heads
        elif kind == "conv" and leaf.shape[-1] % tp == 0:
            spec[-1] = "model"                 # conv channels
        elif kind == "lat" and leaf.ndim >= 3 \
                and leaf.shape[b_dim + 1] % tp == 0:
            spec[b_dim + 1] = "model"          # MLA latent: shard capacity
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def batch_sharding(rules: ShardingRules, batch: Any) -> Any:
    """Input batch: dim 0 over the DP axes (replicated if not divisible)."""
    mesh = rules.mesh
    baxes = tuple(a for a in rules.batch_axes if mesh.shape[a] > 1)
    bsize = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1

    def spec_for(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        ok = baxes and leaf.shape[0] % bsize == 0
        return NamedSharding(mesh, P(baxes if ok else None))

    return jax.tree.map(spec_for, batch)
