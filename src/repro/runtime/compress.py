"""Gradient compression for the scarce cross-pod links.

int8 uniform quantization with per-tensor scale and error feedback
(1-bit-Adam-family trick): the quantization residual is carried in the
training state and added back before the next step's quantization, so the
compression bias telescopes away and convergence is preserved.

Used by the explicit-DP training path (shard_map over the "pod" axis) and
unit-tested for the telescoping property.  Under plain pjit the gradient
all-reduce is inserted by XLA and cannot be intercepted — that trade
(implicit fp32 reduce vs explicit int8 reduce) is a launcher flag.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# the int8 codec lives in repro.quant (shared with the quantized-KV-cache
# path); re-exported here so existing callers keep importing from compress
from repro.quant import dequantize_int8, quantize_int8

__all__ = ["quantize_int8", "dequantize_int8", "compress_residual",
           "compressed_psum", "init_error_state",
           "make_compressed_dp_allreduce"]


def compress_residual(x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(q, scale, error) — error = x - dequant(q) for error feedback."""
    q, scale = quantize_int8(x)
    err = x.astype(jnp.float32) - dequantize_int8(q, scale)
    return q, scale, err


def compressed_psum(tree: Any, axis: str, error_state: Any):
    """int8 all-reduce over ``axis`` with error feedback.

    Must run inside shard_map.  ``error_state`` mirrors ``tree`` (fp32).
    Returns (reduced_tree_fp32_mean, new_error_state).

    Wire cost: 1 byte/element + one fp32 scale per tensor, vs 4 bytes for a
    plain fp32 psum — a 4x cut on the pod-to-pod DCI bottleneck.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g_fb = g.astype(jnp.float32) + e
        q, scale, err = compress_residual(g_fb)
        # int8 summands can overflow int8 — widen to int32 for the wire sum;
        # real deployments use the s8->s32 accumulating all-reduce
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        scale_max = jax.lax.pmax(scale, axis)
        return (q_sum.astype(jnp.float32) * scale_max) / n, err

    flat, treedef = jax.tree.flatten(tree)
    eflat = jax.tree.leaves(error_state)
    out, errs = [], []
    for g, e in zip(flat, eflat):
        r, err = one(g, e)
        out.append(r)
        errs.append(err)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, errs)


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_dp_allreduce(mesh, axis: str = "pod"):
    """shard_map wrapper: gradients sharded over nothing but the DP axis
    (each pod holds its own grads) -> int8 mean across pods."""
    def fn(grads, error_state):
        def inner(g, e):
            return compressed_psum(g, axis, e)
        spec = jax.tree.map(lambda _: P(), grads)
        from repro.models.common import shard_map
        return shard_map(inner, mesh=mesh,
                         in_specs=(spec, spec), out_specs=(spec, spec),
                         check=False)(grads, error_state)
    return fn
