"""Shared int8 quantization helpers.

One implementation serves two very different consumers:

  * the gradient-compression path (``runtime/compress.py``): per-TENSOR
    symmetric scales — a whole gradient tensor shares one fp32 scale, the
    error-feedback loop telescopes the bias away.
  * the quantized KV-cache path (``serving`` + ``kernels``): per-ROW
    symmetric scales — each (token, kv-head) row of a page pool carries
    its own fp32 scale over head_dim.  Per-row (not per-page) matters
    because decode appends ONE row at a time: a page-granular scale would
    have to re-quantize every committed row in the page whenever a new
    outlier row lands, breaking the bit-stability the prefix cache and
    snapshot/restore rely on.  A row, once written, never rescales.

Both are symmetric (no zero point): ``q = round(x / scale)`` clipped to
[-127, 127], ``scale = max|x| / 127``.  Dequant is ``q * scale`` in fp32 —
exactly the multiply the fused-dequant decode kernels perform on each
block after the int8 -> fp32 cast (see kernels/decode_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale) with scalar scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_int8_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 over the LAST axis.

    Returns ``(q, scale)`` with ``q`` shaped like ``x`` (int8) and
    ``scale`` shaped ``x.shape[:-1] + (1,)`` (fp32) — the KV-pool layout,
    where the last axis is head_dim and every leading index is one
    (page, row, kv-head) cache row.  All-zero rows get scale 1e-12/127
    and quantize to exact zeros, so untouched pool rows stay bit-stable."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_int8_rows` (broadcasts the (..., 1)
    scale over head_dim)."""
    return q.astype(jnp.float32) * scale
