"""The assigned input-shape set (same four shapes for every LM arch).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers the prefill graph;
``decode_*`` / ``long_*`` lower ``serve_step`` (ONE new token against a KV
cache of ``seq_len``), per the assignment brief.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = Shape("train_4k", 4_096, 256, "train")
PREFILL_32K = Shape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = Shape("decode_32k", 32_768, 128, "decode")
LONG_500K = Shape("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}
