"""Mamba2-370M — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, max_seq_len=4096,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    ssm_chunk=128, conv_width=4, tie_embeddings=True,
)

SPEC = ArchSpec(
    arch_id="mamba2-370m", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[arXiv:2405.21060; unverified]",
    long_context_ok=True,
    notes="Constant-size decode state (48 layers x (B,32,64,128) fp32) => "
          "long_500k is O(1) per step. vocab 50280 padded to 50432 for the "
          "16-way TP vocab shard (Megatron-style padding).",
)
