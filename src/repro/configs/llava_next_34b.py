"""LLaVA-NeXT-34B — Yi-34B-class decoder with an anyres vision prefix
(backbone only; the ViT frontend is a stub: input_specs provides
precomputed patch embeddings).  [hf:llava-hf/llava-v1.6-mistral-7b-hf
(arch recipe); unverified]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, head_dim=128, max_seq_len=4096,
    vision_tokens=2880,            # anyres: 4 tiles + base, 576 each
    rope_theta=5_000_000.0, tie_embeddings=False,
)

SPEC = ArchSpec(
    arch_id="llava-next-34b", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
    long_context_ok=False,
    notes="56 q-heads not divisible by 16 => batch-parallel attention with "
          "FSDP-gathered weights; MLP (20480) and vocab use TP. The 2880 "
          "vision tokens are a loss-masked prefix.",
)
