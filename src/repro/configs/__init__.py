"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from repro.configs import (deepseek_v2_236b, gemma2_27b, h2o_danube3_4b,
                           llava_next_34b, mamba2_370m, musicgen_medium,
                           phi35_moe, smollm_135m, stablelm_1_6b,
                           zamba2_1_2b)
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.configs.shapes import ALL_SHAPES, SHAPES, Shape

ARCH_SPECS: dict[str, ArchSpec] = {
    spec.arch_id: spec
    for spec in (
        smollm_135m.SPEC,
        h2o_danube3_4b.SPEC,
        stablelm_1_6b.SPEC,
        gemma2_27b.SPEC,
        musicgen_medium.SPEC,
        phi35_moe.SPEC,
        deepseek_v2_236b.SPEC,
        llava_next_34b.SPEC,
        mamba2_370m.SPEC,
        zamba2_1_2b.SPEC,
    )
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCH_SPECS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_SPECS)}")
    return ARCH_SPECS[arch_id]


def all_cells():
    """Every (arch, shape) dry-run cell, in registry order."""
    for spec in ARCH_SPECS.values():
        for shape in spec.shapes():
            yield spec, shape
