"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts, top-2 routing.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, head_dim=128, max_seq_len=4096,
    n_experts=16, experts_per_token=2,
    rope_theta=10_000.0, tie_embeddings=False,
)

SPEC = ArchSpec(
    arch_id="phi3.5-moe-42b-a6.6b", config=CONFIG,
    smoke=reduce_for_smoke(CONFIG),
    source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
    long_context_ok=False,
    notes="16 experts == 16-way model axis: exactly one expert per EP "
          "shard; MoE combine rides the same per-layer psum as TP.",
)
