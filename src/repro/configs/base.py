"""Arch registry plumbing: ArchSpec + the generic smoke-config reducer."""
from __future__ import annotations

import dataclasses

from repro.configs.shapes import ALL_SHAPES, LONG_500K, Shape
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: full config + reduced smoke variant +
    which input shapes apply (long_500k only for sub-quadratic archs)."""
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    source: str                      # [source; verified-tier] from the brief
    long_context_ok: bool = False    # may run long_500k
    notes: str = ""

    def shapes(self) -> tuple[Shape, ...]:
        out = []
        for s in ALL_SHAPES:
            if s is LONG_500K and not self.long_context_ok:
                continue
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> tuple[Shape, ...]:
        return tuple(s for s in ALL_SHAPES if s not in self.shapes())


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Generic family-preserving reducer: tiny layers/width/vocab, same
    block pattern, runs a forward + train step on CPU in seconds."""
    changes: dict = dict(
        n_layers=max(2, 2 * _unit(cfg)),
        d_model=128,
        vocab_size=256,
        max_seq_len=64,
    )
    if cfg.n_heads:
        changes["n_heads"] = 4
        changes["n_kv_heads"] = max(1, int(round(4 * cfg.n_kv_heads / cfg.n_heads)))
        changes["head_dim"] = 32
    if cfg.d_ff:
        changes["d_ff"] = 256
    if cfg.use_mla:
        changes.update(kv_lora_rank=32, q_lora_rank=(24 if cfg.q_lora_rank else 0),
                       rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
    if cfg.n_experts:
        changes.update(n_experts=8, experts_per_token=min(cfg.experts_per_token, 2),
                       moe_d_ff=64,
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       shared_d_ff=64)
    if cfg.first_dense_layers:
        changes.update(first_dense_layers=1, dense_d_ff=256,
                       n_layers=1 + 2 * _unit(cfg))
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.local_global:
        changes["local_window"] = 16
    if cfg.vision_tokens:
        changes["vision_tokens"] = 8
    changes.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)


def _unit(cfg: ModelConfig) -> int:
    if cfg.local_global:
        return 2
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        return cfg.hybrid_attn_every
    return 1
