"""H2O-Danube-3-4B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, head_dim=120, max_seq_len=32768,
    sliding_window=4096,          # mistral-style SWA (window per the series)
    rope_theta=10_000.0, tie_embeddings=False,
)

SPEC = ArchSpec(
    arch_id="h2o-danube-3-4b", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[arXiv:2401.16818; unverified]",
    long_context_ok=True,
    notes="SWA on every layer clips the decode cache to the 4k window => "
          "sub-quadratic by construction; long_500k runs with a 4096-slot "
          "ring cache.",
)
