"""Zamba2-1.2B — Mamba2 backbone + ONE shared attention block applied
every 2 mamba layers (single param copy).  [arXiv:2411.15242; hf]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, max_seq_len=4096,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    ssm_chunk=128, conv_width=4,
    hybrid_attn_every=2, tie_embeddings=True,
)

SPEC = ArchSpec(
    arch_id="zamba2-1.2b", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[arXiv:2411.15242; hf]",
    long_context_ok=True,
    notes="Shared block input is concat(hidden, embeddings) -> 2d->d "
          "projection (Zamba2's fused-input trick). The shared block's KV "
          "cache grows with context; at long_500k it is the dominant state "
          "(19 applications x 500k KV) — recorded in the roofline as the "
          "memory term. Pattern unit = 2 mamba layers + 1 shared-attn use.",
)
