"""StableLM-2-1.6B — dense LM with partial rotary embeddings (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, head_dim=64, max_seq_len=4096,
    rope_theta=10_000.0, rope_fraction=0.25, tie_embeddings=False,
)

SPEC = ArchSpec(
    arch_id="stablelm-1.6b", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
    long_context_ok=False,
    notes="LayerNorm-with-bias in the original is carried as RMSNorm here "
          "(identical roofline class; recorded in DESIGN.md Sec 8).",
)
