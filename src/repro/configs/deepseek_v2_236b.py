"""DeepSeek-V2 (236B total / 21B active) — MLA (kv_lora 512) + 160 routed
experts top-6 + 2 shared experts, first layer dense.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536,                     # brief lists the routed-expert hidden
    vocab_size=102400, max_seq_len=8192,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    n_experts=160, experts_per_token=6, moe_d_ff=1536,
    route_group_limit=3,           # device-limited routing (paper Sec 2.1.2)
    n_shared_experts=2, shared_d_ff=1536,
    first_dense_layers=1, dense_d_ff=12288,
    rope_theta=10_000.0, tie_embeddings=False,
)

SPEC = ArchSpec(
    arch_id="deepseek-v2-236b", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[arXiv:2405.04434; hf]",
    long_context_ok=False,
    notes="MLA decode uses the absorbed-matmul path: the cache is the "
          "compressed (c_kv 512 + rope 64) latent per token, shared across "
          "all 128 heads. 160 experts / 16 EP shards = 10 experts/shard.",
)
