"""MusicGen-medium — decoder-only over 4 EnCodec codebooks (backbone only;
the EnCodec frontend is a stub: input_specs provides precomputed codes).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, head_dim=64, max_seq_len=4096,
    n_codebooks=4, tie_embeddings=False, act="gelu", gated_mlp=False,
)

SPEC = ArchSpec(
    arch_id="musicgen-medium", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[arXiv:2306.05284; hf]",
    long_context_ok=False,
    notes="Backbone per the brief: per-codebook embeddings are summed, four "
          "parallel LM heads; the delay-pattern scheduler and text "
          "conditioning live in the (stubbed) frontend. Sinusoidal "
          "positions are carried as RoPE (DESIGN.md Sec 8). 24 heads not "
          "divisible by 16 => batch-parallel attention.",
)
