"""Gemma2-27B — local/global alternating attention + logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab_size=256000, head_dim=128, max_seq_len=8192,
    local_global=True, local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    # gemma2-27b: query_pre_attn_scalar = d_model / n_heads = 144
    query_scale=144.0 ** -0.5,
    post_norms=True, embed_scale=True,
    rope_theta=10_000.0, tie_embeddings=True, act="gelu",
)

SPEC = ArchSpec(
    arch_id="gemma2-27b", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[arXiv:2408.00118; hf]",
    long_context_ok=False,
    notes="Pattern-unit scan over (local, global) layer pairs keeps both "
          "programs distinct in HLO (honest FLOP count). Global layers are "
          "full attention => long_500k skipped.",
)
