"""SmolLM-135M — llama-arch small dense LM.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ArchSpec, reduce_for_smoke
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab_size=49152, head_dim=64, max_seq_len=4096,
    rope_theta=10_000.0, tie_embeddings=True,
)

SPEC = ArchSpec(
    arch_id="smollm-135m", config=CONFIG, smoke=reduce_for_smoke(CONFIG),
    source="[hf:HuggingFaceTB/SmolLM-135M; hf]",
    long_context_ok=False,
    notes="9 q-heads / 3 kv-heads are not divisible by the 16-way model "
          "axis: attention runs batch-parallel with FSDP-gathered weights "
          "(see runtime.sharding); MLP/vocab still use TP.",
)
