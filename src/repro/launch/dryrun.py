"""Multi-pod dry-run — deliverable (e).

For every (architecture x input-shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(*input_specs(...))
        compiled = lowered.compile()
        compiled.memory_analysis()    # proves it fits 16 GB/chip
        compiled.cost_analysis()      # FLOPs / bytes for the roofline

plus an HLO parse summing the operand bytes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
— cost_analysis does not report collective traffic.

Results land in artifacts/dryrun/<mesh>/<arch>__<shape>.json; the roofline
benchmark and EXPERIMENTS.md read from there.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks the device count on first init.
#   (__future__ is the only legal statement allowed above this line.)

import argparse
import dataclasses
import json
import pathlib
import re
import time
import traceback

import jax

from repro.configs import ARCH_SPECS, SHAPES, get_arch
from repro.configs.base import ArchSpec
from repro.configs.shapes import Shape
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import opt_state_sharding
from repro.runtime.sharding import batch_sharding, build_rules, cache_sharding
from repro.runtime.steps import (StepConfig, make_prefill_step,
                                 make_serve_step, make_train_step)

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"=\s*(\S+?)\[?([\d,]*)\]?\{?[^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _dtype_bytes(dt: str) -> int:
    return {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
            "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
            "c64": 8, "c128": 16, "s64": 8, "u64": 8}.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum result-shape bytes of every collective in post-SPMD HLO."""
    out: dict[str, dict[str, float]] = {}
    # result types look like:  bf16[16,4096]{1,0} all-gather(...)
    pat = re.compile(
        r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    for m in pat.finditer(hlo_text):
        tuple_types, dt, dims, op = m.groups()
        nbytes = 0
        if tuple_types:                          # tuple result (async pairs)
            for t in re.finditer(r"(\w+)\[([\d,]*)\]", tuple_types):
                d, ds = t.groups()
                n = 1
                for x in ds.split(","):
                    if x:
                        n *= int(x)
                nbytes += n * _dtype_bytes(d)
        else:
            n = 1
            for x in (dims or "").split(","):
                if x:
                    n *= int(x)
            nbytes = n * _dtype_bytes(dt)
        slot = out.setdefault(op, {"count": 0, "bytes": 0.0})
        slot["count"] += 1
        slot["bytes"] += float(nbytes)
    return out


def lower_cell(spec: ArchSpec, shape: Shape, mesh, step_cfg: StepConfig):
    """Build + lower + compile one cell; returns the record dict."""
    cfg = spec.config
    rules = build_rules(cfg, mesh, sequence_shard=step_cfg.sequence_shard,
                        moe_strategy=step_cfg.moe_strategy)
    ins = S.input_specs(spec, shape, step_cfg)
    t0 = time.time()

    with mesh:
        if ins["kind"] == "train":
            psh = rules.param_sharding(ins["axes"])
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(mesh, PartitionSpec())
            state_sh = {"params": psh,
                        "opt": opt_state_sharding(psh, ins["state"]["opt"], mesh),
                        "step": rep}
            batch_sh = batch_sharding(rules, ins["batch"])
            step = make_train_step(cfg, step_cfg, rules)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(ins["state"], ins["batch"])
        elif ins["kind"] == "prefill":
            psh = rules.param_sharding(ins["axes"])
            batch_sh = batch_sharding(rules, ins["batch"])
            step = make_prefill_step(cfg, step_cfg, rules,
                                     max_len=ins["max_len"])
            cache_abs = jax.eval_shape(step, ins["params"], ins["batch"])[1]
            cache_sh = cache_sharding(rules, cache_abs, cfg)
            jitted = jax.jit(step, in_shardings=(psh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(ins["params"], ins["batch"])
        else:                                      # decode
            psh = rules.param_sharding(ins["axes"])
            cache_sh = cache_sharding(rules, ins["cache"], cfg)
            tok_sh = batch_sharding(rules, ins["tokens"])
            step = make_serve_step(cfg, step_cfg, rules)
            jitted = jax.jit(step, in_shardings=(psh, cache_sh, tok_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(ins["params"], ins["cache"], ins["tokens"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    from repro.launch import hloparse
    cost = hloparse.xla_cost(compiled)
    hlo = hloparse.analyze(compiled.as_text())

    record = {
        "arch": spec.arch_id,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
        # honest per-device numbers: while bodies multiplied by trip count
        "flops_per_device": float(hlo["dot_flops"]),
        "hbm_bytes_per_device": float(hlo["hbm_bytes"]),
        "collective_bytes_per_device": float(hlo["collective_bytes"]),
        # raw cost_analysis (loop bodies counted ONCE — reference only)
        "xla_flops_raw": float(cost.get("flops", -1.0)),
        "xla_bytes_raw": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", -1)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", -1)),
        },
        "collectives": hlo["collectives"],
        "params_total": spec.config.param_count(),
        "params_active": spec.config.active_param_count(),
        "step_cfg": {"n_micro": step_cfg.n_micro, "remat": step_cfg.remat,
                     "sequence_shard": step_cfg.sequence_shard,
                     "moe_strategy": step_cfg.moe_strategy},
    }
    return record


def default_step_cfg(spec: ArchSpec, shape: Shape) -> StepConfig:
    """Per-cell microbatching: keep per-device live activations bounded."""
    if shape.kind != "train":
        return StepConfig(n_micro=1, remat="none")
    # per-device batch = global / DP shards (16 single-pod, 32 multi-pod);
    # 8 microbatches keeps layer boundaries < ~100 MB for the big archs
    n_micro = 8 if shape.global_batch >= 64 else 1
    return StepConfig(n_micro=n_micro, remat="full")


def run_cells(arch_ids, shape_names, meshes, out_dir: pathlib.Path,
              step_cfg: StepConfig | None = None, tag: str = "",
              pad_heads: bool = False):
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        mdir = out_dir / mesh_name
        mdir.mkdir(parents=True, exist_ok=True)
        for aid in arch_ids:
            spec = get_arch(aid)
            for sname in shape_names:
                shape = SHAPES[sname]
                if shape not in spec.shapes():
                    print(f"SKIP  {aid} x {sname} (long-context not "
                          f"applicable; see DESIGN.md)")
                    continue
                scfg = step_cfg or default_step_cfg(spec, shape)
                run_spec = spec
                if pad_heads and spec.config.n_heads:
                    import dataclasses as _dc
                    tp = 16
                    hq = -(-spec.config.n_heads // tp) * tp
                    hkv = spec.config.n_kv_heads
                    if spec.config.n_kv_heads == spec.config.n_heads:
                        hkv = hq                      # MHA: pad both
                    if hq != spec.config.n_heads or hkv != spec.config.n_kv_heads:
                        g = hq // hkv
                        if (spec.config.n_heads - 1) // g < spec.config.n_kv_heads:
                            run_spec = _dc.replace(
                                spec, config=_dc.replace(
                                    spec.config, pad_q_heads_to=hq,
                                    pad_kv_heads_to=hkv))
                label = f"{aid} x {sname} @ {mesh_name}"
                fname = mdir / f"{aid}__{sname}{tag}.json"
                try:
                    rec = lower_cell(run_spec, shape, mesh, scfg)
                    rec["status"] = "ok"
                    fname.write_text(json.dumps(rec, indent=1))
                    print(f"OK    {label}: compile={rec['seconds_compile']:.1f}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll={sum(c['bytes'] for c in rec['collectives'].values())/2**30:.2f} GiB")
                except Exception as e:
                    rec = {"arch": aid, "shape": sname, "mesh": mesh_name,
                           "status": "fail", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    fname.write_text(json.dumps(rec, indent=1))
                    print(f"FAIL  {label}: {type(e).__name__}: {str(e)[:200]}")
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--n-micro", type=int, default=0, help="override microbatches")
    ap.add_argument("--remat", default="", choices=["", "none", "dots", "full"])
    ap.add_argument("--sequence-shard", action="store_true")
    ap.add_argument("--moe-strategy", default="", choices=["", "gather", "a2a"])
    ap.add_argument("--pad-heads", action="store_true",
                    help="pad q/kv heads to the model-axis multiple (TP)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    arch_ids = [args.arch] if args.arch else list(ARCH_SPECS)
    shape_names = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    step_cfg = None
    if args.n_micro or args.remat or args.sequence_shard or args.moe_strategy:
        step_cfg = StepConfig(n_micro=args.n_micro or 1,
                              remat=args.remat or "full",
                              sequence_shard=args.sequence_shard,
                              moe_strategy=args.moe_strategy or "gather")

    results = run_cells(arch_ids, shape_names, meshes,
                        pathlib.Path(args.out), step_cfg, tag=args.tag,
                        pad_heads=args.pad_heads)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} cells lowered+compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
