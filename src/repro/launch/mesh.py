"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).

Topology (TPU v5e target):
  * single pod: 16 x 16 = 256 chips, axes ("data", "model")
  * multi pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")

The "pod" axis only ever carries batch (pure DP; one grad all-reduce per
step) — DCI links between pods are ~10x scarcer than intra-pod ICI, and the
design target is 1000+ nodes: nothing below assumes pod count <= 2.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
