"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for every
model input / state / cache — weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import Shape
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.runtime.steps import StepConfig, init_train_state


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: Shape) -> dict[str, Any]:
    gb, S = shape.global_batch, shape.seq_len
    if cfg.n_codebooks:
        tok = (gb, S, cfg.n_codebooks)
    else:
        tok = (gb, S)
    batch = {"inputs": sds(tok, "int32"), "targets": sds(tok, "int32")}
    if cfg.vision_tokens:
        # seq budget includes the vision prefix; text gets the rest
        text = S - cfg.vision_tokens
        batch["inputs"] = sds((gb, text), "int32")
        batch["targets"] = sds((gb, text), "int32")
        batch["image_embeds"] = sds((gb, cfg.vision_tokens, cfg.d_model),
                                    cfg.dtype)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: Shape) -> dict[str, Any]:
    gb, S = shape.global_batch, shape.seq_len
    if cfg.n_codebooks:
        tok = (gb, S, cfg.n_codebooks)
    else:
        tok = (gb, S)
    batch = {"inputs": sds(tok, "int32")}
    if cfg.vision_tokens:
        batch["inputs"] = sds((gb, S - cfg.vision_tokens), "int32")
        batch["image_embeds"] = sds((gb, cfg.vision_tokens, cfg.d_model),
                                    cfg.dtype)
    return batch


def decode_token_specs(cfg: ModelConfig, shape: Shape) -> jax.ShapeDtypeStruct:
    gb = shape.global_batch
    if cfg.n_codebooks:
        return sds((gb, 1, cfg.n_codebooks), "int32")
    return sds((gb, 1), "int32")


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: tfm.init_cache(cfg, batch, max_len, dtype=cfg.dtype))


def abstract_train_state(cfg: ModelConfig, step_cfg: StepConfig):
    """(state ShapeDtypeStruct tree, logical-axes tree) without allocating."""
    holder: dict[str, Any] = {}

    def f(key):
        state, axes = init_train_state(key, cfg, step_cfg)
        holder["axes"] = axes
        return state

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, holder["axes"]


def abstract_params(cfg: ModelConfig):
    holder: dict[str, Any] = {}

    def f(key):
        params, axes = tfm.init_lm(key, cfg)
        holder["axes"] = axes
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, holder["axes"]


def input_specs(spec: ArchSpec, shape: Shape, step_cfg: StepConfig):
    """Everything the dry-run lowers for one (arch, shape) cell."""
    cfg = spec.config
    if shape.kind == "train":
        state, axes = abstract_train_state(cfg, step_cfg)
        return {"kind": "train", "state": state, "axes": axes,
                "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        params, axes = abstract_params(cfg)
        return {"kind": "prefill", "params": params, "axes": axes,
                "batch": prefill_batch_specs(cfg, shape),
                "max_len": shape.seq_len}
    # decode: one new token against a seq_len-deep cache
    params, axes = abstract_params(cfg)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    return {"kind": "decode", "params": params, "axes": axes,
            "cache": cache, "tokens": decode_token_specs(cfg, shape)}
