"""Post-SPMD HLO analysis with while-loop trip-count rollup.

``compiled.cost_analysis()`` famously counts each while body ONCE — a
scan-over-layers train step under-reports FLOPs by ~n_layers x n_micro.
XLA records ``backend_config={"known_trip_count":{"n":...}}`` on while ops
it has bounded, so we parse the HLO text into computations, then roll up

  * matmul FLOPs      — every ``dot`` op: 2 x numel(result) x K,
  * collective bytes  — result bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute
                        (sync or -start async form),

multiplying through nested loop trip counts.  This is the honest per-device
profile the roofline terms are derived from.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s64": 8, "u64": 8, "f8e4m3fn": 1,
                "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_DOT_RE = re.compile(r"\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _numel_bytes(type_str: str) -> tuple[int, int]:
    """(numel, bytes) of the FIRST shape in a type string (tuples summed)."""
    total_n = total_b = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total_n += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_n, total_b


@dataclasses.dataclass
class Computation:
    name: str
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: dict[str, dict[str, float]] = dataclasses.field(default_factory=dict)
    whiles: list[tuple[str, int]] = dataclasses.field(default_factory=list)
    calls: list[str] = dataclasses.field(default_factory=list)


# ops whose operands/results do NOT represent HBM traffic
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "token",
             "opt-barrier", "partition-id", "replica-id", "iota"}


def parse_hlo(txt: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    shapes: dict[str, str] = {}          # %name -> type str (per computation)

    for line in txt.splitlines():
        mc = _COMP_RE.match(line.strip())
        if mc and line.rstrip().endswith("{"):
            name = mc.group(1).lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            shapes = {}
            if line.strip().startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        iname, rest = mi.groups()
        # record result type for operand-shape lookups
        tm = _SHAPE_RE.search(rest)
        if tm:
            shapes[iname] = rest[:rest.find(" ", tm.end())] \
                if " " in rest[tm.end():] else rest

        # -- while ---------------------------------------------------------
        if _WHILE_RE.search(rest):
            bm = _BODY_RE.search(rest)
            tm2 = _TRIP_RE.search(rest)
            trip = int(tm2.group(1)) if tm2 else 1
            if bm:
                cur.whiles.append((bm.group(1).lstrip("%"), trip))
            continue

        # -- call / fusion-with-computation / conditional --------------------
        for cm in re.finditer(r"(?:to_apply|called_computations|"
                              r"true_computation|false_computation|"
                              r"branch_computations)=\{?(%[\w.\-]+)", rest):
            pass    # reductions etc — negligible flops, skip

        if re.search(r"=\s*\S+\s+call\(", rest) or " fusion(" in rest:
            km = re.search(r"(?:to_apply|calls)=(%[\w.\-]+)", rest)
            if km:
                cur.calls.append(km.group(1).lstrip("%"))

        # -- collectives -----------------------------------------------------
        # rest looks like:  bf16[36,64]{1,0} all-gather(%p), channel_id=...
        opm = re.match(r"(\([^)]*\)|\S+)\s+([\w\-]+)\(", rest)
        opname = opm.group(2) if opm else ""
        base_op = opname.removesuffix("-start")
        if base_op in COLLECTIVES and not opname.endswith("-done"):
            head = opm.group(1)
            _, nbytes = _numel_bytes(head)
            if opname.endswith("-start"):
                nbytes //= 2              # async tuple repeats the buffer
            slot = cur.coll.setdefault(base_op, {"count": 0, "bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += float(nbytes)
            continue

        # -- HBM traffic proxy -------------------------------------------------
        # post-fusion, each materialized op reads its operands and writes its
        # result once: traffic ~= result bytes + operand bytes (shape-table
        # lookup).  Free/structural ops are skipped.  This is the loop-
        # adjusted replacement for cost_analysis' "bytes accessed".
        if opm and opname not in _FREE_OPS and not opname.endswith("-done"):
            _, rbytes = _numel_bytes(opm.group(1))
            traffic = float(rbytes)
            om2 = re.search(rf"{re.escape(opname)}\(([^)]*)\)", rest)
            if om2:
                for operand in om2.group(1).split(","):
                    operand = operand.strip()
                    if operand.startswith("%") and operand in shapes:
                        _, ob = _numel_bytes(shapes[operand])
                        traffic += float(ob)
            cur.hbm_bytes += traffic

        # -- dots ------------------------------------------------------------
        if opname == "dot":
            res_head = rest.split("dot(")[0]
            res_n, _ = _numel_bytes(res_head)
            cm = _CONTRACT_RE.search(rest)
            k = 1
            opm = re.search(r"dot\(([^)]*)\)", rest)
            if cm and opm:
                lhs_name = opm.group(1).split(",")[0].strip()
                lhs_type = shapes.get(lhs_name, "")
                sm = _SHAPE_RE.search(lhs_type)
                if sm:
                    dims = [int(x) for x in sm.group(2).split(",") if x]
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            cur.dot_flops += 2.0 * res_n * k

    return comps, entry


def rollup(comps: dict[str, Computation], entry: str) -> dict[str, Any]:
    """Total dot FLOPs + collective bytes of the entry, loop-multiplied."""
    memo: dict[str, tuple[float, dict]] = {}

    def visit(name: str) -> tuple[float, float, dict[str, dict[str, float]]]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0, {}
        flops = c.dot_flops
        hbm = c.hbm_bytes
        coll: dict[str, dict[str, float]] = {
            k: dict(v) for k, v in c.coll.items()}
        for callee in c.calls:
            f2, b2, c2 = visit(callee)
            flops += f2
            hbm += b2
            _merge(coll, c2, 1)
        for body, trip in c.whiles:
            f2, b2, c2 = visit(body)
            flops += trip * f2
            hbm += trip * b2
            _merge(coll, c2, trip)
        memo[name] = (flops, hbm, coll)
        return memo[name]

    flops, hbm, coll = visit(entry)
    return {"dot_flops": flops, "hbm_bytes": hbm, "collectives": coll,
            "collective_bytes": sum(v["bytes"] for v in coll.values())}


def _merge(dst, src, mult):
    for op, v in src.items():
        slot = dst.setdefault(op, {"count": 0, "bytes": 0.0})
        slot["count"] += v["count"] * mult
        slot["bytes"] += v["bytes"] * mult


def analyze(hlo_text: str) -> dict[str, Any]:
    comps, entry = parse_hlo(hlo_text)
    out = rollup(comps, entry)
    out["n_computations"] = len(comps)
    return out


def xla_cost(compiled) -> dict[str, float]:
    """``compiled.cost_analysis()`` normalised across jax versions: recent
    jax returns one dict, older versions a list of per-device dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
