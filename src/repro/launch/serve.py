"""Serving launcher: batched prefill + host-free multi-token decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 8 --prompt-len 32 --gen 16 --decode-chunk 8

Implements the O-RAN inference-host path (models deployed as xAPPs):
requests arrive with ragged prompts, are right-aligned into a fixed prefill
batch, decoded with the ring-buffer cache, and FROST caps the device using
the *decode* roofline (decode is memory-bound, so deep caps are near-free —
the paper's central trade, measured rather than assumed).

Decode runs in fused chunks of ``--decode-chunk`` tokens: sampling + cache
update happen inside one jitted ``lax.scan`` with a donated cache
(runtime.steps.make_decode_loop), so there is no host round-trip per token.
Every chunk publishes ONE ``StepDone`` + ``PowerSampled`` onto the bus with
the *measured* wall time (the analytic device estimate remains the energy
stand-in where no meter exists); the ``OnlineCapProfiler`` amortises its
probes across the live token stream and cap commands are honoured between
chunks through the enforcement backend.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.control import CapApplied, EventBus, StepDone
from repro.control.online import OnlineCapProfiler
from repro.core import (BALANCED, PowerCappedDevice, QoSPolicy, TPU_V5E,
                        WorkloadProfile)
from repro.core.profiler import RecordingBackend
from repro.data import DataConfig, TokenBatches
from repro.launch.mesh import make_host_mesh
from repro.runtime.sharding import build_rules
from repro.runtime.steps import (StepConfig, make_decode_loop,
                                 make_prefill_step)
from repro.models import transformer as tfm
from repro.telemetry.meters import AnalyticDeviceMeter, CpuProcessMeter, DramMeter
from repro.telemetry.sampler import PowerSampler


def decode_workload(cfg, requests: int) -> WorkloadProfile:
    """Decode-step roofline from first principles: every generated token
    streams the full parameter set from HBM once (memory-bound — the reason
    deep caps are near-free while serving), with 2 FLOPs per param per
    sequence of compute on top."""
    p = float(cfg.param_count())
    return WorkloadProfile(
        name=f"{cfg.name}-decode",
        flops_per_step=2.0 * p * requests,
        hbm_bytes_per_step=2.0 * p,          # bf16 weights once per token
        samples_per_step=requests,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per fused lax.scan decode chunk (1 = the "
                         "old per-token host loop cadence)")
    ap.add_argument("--no-frost", action="store_true",
                    help="disable the FROST control plane")
    ap.add_argument("--edp-exponent", type=float, default=2.0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    step_cfg = StepConfig(remat="none")
    mesh = make_host_mesh()
    rules = build_rules(cfg, mesh) if mesh.devices.size > 1 else None

    params, _ = tfm.init_lm(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, rules, max_len=max_len))

    # fused decode loops, one executable per chunk size actually used (the
    # final ragged chunk compiles its own); the cache is donated so the ring
    # buffers update in place across chunks.  AOT-compiled on first use so
    # compile time never lands in a chunk's measured duration_s — the
    # profiler would read it as a grossly slow probe and flag drift.
    loops: dict[int, object] = {}

    def chunk_loop(n: int, *loop_args):
        if n not in loops:
            fn = jax.jit(make_decode_loop(cfg, step_cfg, rules, n),
                         donate_argnums=(1,))
            loops[n] = fn.lower(*loop_args).compile()  # lowering donates nothing
        return loops[n]

    # -- FROST control plane (paper Fig 1, event-driven) ----------------------
    bus = EventBus()
    backend = RecordingBackend()
    device = PowerCappedDevice(TPU_V5E)
    wl = decode_workload(cfg, args.requests)
    meter = AnalyticDeviceMeter(device, wl)
    sampler = PowerSampler({"gpu": meter, "cpu": CpuProcessMeter(),
                            "dram": DramMeter(4, 16)},
                           rate_hz=0.1, bus=bus, node_id="serve-0")
    cap_log = bus.tap(CapApplied)        # lossless cap-command accounting
    profiler = None
    if not args.no_frost:
        policy = QoSPolicy(policy_id=f"serve-ed{args.edp_exponent:g}p",
                           edp_exponent=args.edp_exponent) \
            if args.edp_exponent != BALANCED.edp_exponent else BALANCED
        profiler = OnlineCapProfiler(
            bus, backend, policy=policy, node_id="serve-0",
            model_id=cfg.name, steps_per_probe=1, hold_steps=8)

    # synth request batch
    data = TokenBatches(DataConfig(seed=args.seed, vocab_size=cfg.vocab_size,
                                   seq_len=args.prompt_len,
                                   global_batch=args.requests,
                                   n_codebooks=cfg.n_codebooks))
    prompts = data.batch(0)["inputs"]

    t0 = time.time()
    last_logits, cache = prefill(params, {"inputs": jnp.asarray(prompts)})
    nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    def emit_chunk(step_idx: int, n_tok: int, wall_s: float) -> float:
        """One fused chunk's telemetry: the *measured* wall time and token
        count feed the profiler; the cap currently in force shapes the
        (simulated) accelerator's energy — the analytic estimate remains the
        energy stand-in where no meter exists.  Returns the chunk's J."""
        cap = backend.current_cap()          # honour latest cap command
        meter.set_cap(cap)
        meter.set_workload(wl, busy=True)
        est = device.estimate(wl, cap)
        energy_j = est.energy_j * n_tok      # wl is per decode token batch
        sampler.sample_once()                # -> PowerSampled on the bus
        bus.publish(StepDone(node_id="serve-0", step=step_idx,
                             duration_s=wall_s,
                             samples=n_tok * args.requests,
                             energy_j=energy_j, model_id=cfg.name))
        return energy_j

    generated = [np.asarray(nxt)[:, None]]   # token sampled from prefill
    tok = nxt[:, None]                       # (B, 1) or (B, 1, n_cb)
    remaining = args.gen - 1
    chunk = max(1, args.decode_chunk)
    decode_energy_j = 0.0
    step_idx = 0
    t_decode = 0.0                           # execution only, compile excluded
    while remaining > 0:
        n = min(chunk, remaining)
        loop = chunk_loop(n, params, cache, tok)
        t_c = time.perf_counter()
        toks, cache = loop(params, cache, tok)
        toks = jax.block_until_ready(toks)
        wall = time.perf_counter() - t_c
        t_decode += wall
        decode_energy_j += emit_chunk(step_idx, n, wall)
        generated.append(np.asarray(toks))
        tok = toks[:, -1:]
        remaining -= n
        step_idx += 1
    toks_out = np.concatenate(generated, axis=1)

    # the first token came from prefill: tok/s and J/token charge only the
    # (gen - 1) * requests tokens the decode loop actually produced
    n_decoded = (args.gen - 1) * args.requests
    tok_per_s = n_decoded / max(t_decode, 1e-9)
    j_per_tok = decode_energy_j / max(n_decoded, 1)
    print(f"[serve] prefill {args.requests}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; decode {n_decoded} tokens in "
          f"{t_decode*1e3:.0f} ms ({tok_per_s:.0f} tok/s measured, "
          f"fused chunks of {chunk}; {j_per_tok:.3g} J/token analytic)")
    print(f"[serve] sample continuation: {toks_out[0].ravel()[:16].tolist()}")

    if profiler is not None:
        caps = cap_log
        probes = sum(1 for c in caps if c.reason == "probe")
        decisions = [c for c in caps if c.reason == "decision"]
        timeline = " -> ".join(f"{c.cap:.0%}({c.reason[0]})" for c in caps[:12])
        print(f"[frost-ctrl] {len(caps)} cap commands mid-run "
              f"({probes} amortised probes, {len(decisions)} decisions): "
              f"{timeline}{' ...' if len(caps) > 12 else ''}")
        if profiler.decision is not None:
            d = profiler.decision
            print(f"[frost-ctrl] serving cap {d.cap:.0%} of TDP "
                  f"(pred. energy saving {d.predicted_energy_saving:+.1%}, "
                  f"delay {d.predicted_delay_increase:+.1%}, "
                  f"fit {'accepted' if d.fit_accepted else 'fallback'})")
        profiler.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
