"""Serving launcher: batched prefill + decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 8 --prompt-len 32 --gen 16

Implements the O-RAN inference-host path (models deployed as xAPPs):
requests arrive with ragged prompts, are right-aligned into a fixed prefill
batch, decoded with the ring-buffer cache, and FROST caps the device using
the *decode* roofline (decode is memory-bound, so deep caps are near-free —
the paper's central trade, measured rather than assumed).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import QoSPolicy
from repro.data import DataConfig, TokenBatches
from repro.launch.mesh import make_host_mesh
from repro.runtime.sharding import build_rules
from repro.runtime.steps import (StepConfig, make_prefill_step,
                                 make_serve_step)
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    step_cfg = StepConfig(remat="none")
    mesh = make_host_mesh()
    rules = build_rules(cfg, mesh) if mesh.devices.size > 1 else None

    params, _ = tfm.init_lm(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, rules, max_len=max_len))
    serve = jax.jit(make_serve_step(cfg, step_cfg, rules), donate_argnums=(1,))

    # synth request batch
    data = TokenBatches(DataConfig(seed=args.seed, vocab_size=cfg.vocab_size,
                                   seq_len=args.prompt_len,
                                   global_batch=args.requests,
                                   n_codebooks=cfg.n_codebooks))
    prompts = data.batch(0)["inputs"]

    t0 = time.time()
    last_logits, cache = prefill(params, {"inputs": jnp.asarray(prompts)})
    nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    generated = [nxt]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok = generated[-1].reshape(args.requests, 1, -1) if cfg.n_codebooks \
            else generated[-1].reshape(args.requests, 1)
        nxt, cache = serve(params, cache, tok)
        generated.append(nxt)
    toks_out = np.stack([np.asarray(g) for g in generated], axis=1)
    t_decode = time.time() - t0

    n_gen = args.gen * args.requests
    print(f"[serve] prefill {args.requests}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; decode {n_gen} tokens in "
          f"{t_decode*1e3:.0f} ms ({n_gen/max(t_decode,1e-9):.0f} tok/s)")
    print(f"[serve] sample continuation: {toks_out[0].ravel()[:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
