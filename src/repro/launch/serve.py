"""Serving launcher — thin CLI over the continuous-batching engine.

    # continuous batching under a Poisson arrival trace (the O-RAN xAPP
    # serving path: ragged requests joining and finishing mid-decode)
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --traffic poisson --requests 8 --gen 16

    # static-batch baseline (everything arrives at once, one fused run)
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --traffic batch --requests 8 --prompt-len 32 --gen 16

Two serving modes share the decode fast path (fused ``lax.scan`` chunks,
split-K decode-attention kernels, AOT-compiled executables):

  * ``batch``   — the fixed-batch run-to-completion baseline: one prefill,
    then fused ring-buffer decode chunks.  The final ragged chunk is padded
    to ``--decode-chunk`` and the overrun discarded, so the whole run uses
    ONE decode executable.
  * ``poisson`` — ``repro.serving.ServeEngine``: requests join fixed decode
    slots mid-stream (prefill-on-join into the paged KV cache) and free on
    EOS / token budget.  J/token charges only occupied slots.  The engine
    shares cached prompt prefixes across requests (``--no-prefix-cache``
    disables) and preempts/re-queues on page pressure (``--no-preempt``
    restores the old reserve-everything admission).

``--shared-prefix-len N`` makes the traffic realistic for prefix sharing
in BOTH modes: every prompt becomes one of ``--prompt-pools`` fixed shared
heads (system prompt / few-shot header stand-ins) plus a unique suffix —
the engine then prefills only the uncached suffix and reports the prompt
tokens (and modelled prefill joules) it never had to compute.

``--spec-k K`` turns either mode speculative: each cache sweep verifies K
self-drafted tokens plus one bonus (``--drafter ngram`` prompt-lookup or
``repeat``), emitting 1..K+1 tokens per sweep — greedy output is
bit-identical to the plain loop, J/accepted-token drops with acceptance,
and admission control prices occupancy at the *effective* tok/s (see
docs/speculative_decoding.md).

FROST (unless ``--no-frost``, which skips building the sampler/meters and
publishes nothing): every chunk emits one ``StepDone`` + ``PowerSampled``
with the *measured* wall time and the useful token count; the
``OnlineCapProfiler`` amortises probes over the live stream and cap
commands are honoured between chunks.  ``--power-budget`` additionally
gates admission on the predicted board draw under the cap in force.

``--chaos "kind@step[:duration[:arg]],..."`` arms a seeded fault injector
on the engine's decode-step clock (poisson mode only) — slot/engine
crashes, KV-page corruption, telemetry drops, stalls, and power
emergencies.  An ``engine_crash`` is recovered here: the launcher restores
from the last committed snapshot (``--snapshot-dir`` / ``--snapshot-every``)
and ``resume()``s, requeueing the dead engine's in-flight requests with
zero token loss.  A :class:`ServingSupervisor` rides along: engine chunks
are its heartbeats, wall-time inflation becomes a published ``NodeDerated``
derate estimate.  See docs/fault_tolerance.md.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.control import CapApplied, EventBus, StepDone
from repro.control.online import OnlineCapProfiler
from repro.core import (BALANCED, PowerCappedDevice, QoSPolicy, TPU_V5E,
                        WorkloadProfile)
from repro.core.profiler import RecordingBackend
from repro.data import DataConfig, TokenBatches
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh
from repro.runtime.chaos import ChaosBus, FaultInjector
from repro.runtime.fault import ServingSupervisor
from repro.runtime.sharding import build_rules
from repro.runtime.speculate import get_drafter
from repro.runtime.steps import (StepConfig, make_decode_loop,
                                 make_prefill_step,
                                 make_speculative_decode_loop,
                                 with_decode_policy)
from repro.models import transformer as tfm
from repro.serving import (EnergyAwareAdmission, EngineConfig, EngineCrash,
                           ServeEngine, batch_trace, poisson_trace)
from repro.telemetry.meters import AnalyticDeviceMeter, CpuProcessMeter, DramMeter
from repro.telemetry.sampler import PowerSampler


def _parse_kv_splits(value: str | int) -> str | int:
    """CLI form of ``KernelPolicy.kv_splits``: 'auto' or a positive int."""
    if value == "auto":
        return "auto"
    n = int(value)
    if n < 1:
        raise ValueError(f"--kv-splits must be 'auto' or >= 1, got {value!r}")
    return n


def decode_workload(cfg, requests: int,
                    tokens_per_step: float = 1.0) -> WorkloadProfile:
    """Decode-step roofline from first principles: every decode step streams
    the full parameter set from HBM once (memory-bound — the reason deep
    caps are near-free while serving), with 2 FLOPs per param per *live*
    sequence of compute on top.  Under partial occupancy the HBM term is
    unchanged (weights stream regardless) while compute scales with the
    requests actually served — utilisation-honest.

    ``tokens_per_step`` is the speculative multiplier — tokens per sequence
    per cache sweep.  Energy callers pass the tokens *scored* (K+1,
    accepted or not: the FLOPs actually burned); admission passes the
    tokens *emitted* (effective throughput).  Either way compute and
    samples scale with it while the HBM term does NOT — that asymmetry is
    the whole J/token argument for speculation on a memory-bound path."""
    p = float(cfg.param_count())
    tps = max(tokens_per_step, 1.0)
    return WorkloadProfile(
        name=f"{cfg.name}-decode",
        flops_per_step=2.0 * p * max(requests, 1) * tps,
        hbm_bytes_per_step=2.0 * p,          # bf16 weights once per sweep
        samples_per_step=max(requests, 1) * tps,
    )


class FrostPlane:
    """The control-plane wiring for a serving run: bus, simulated capped
    device, analytic meter + sampler, online profiler, cap ledger.  Built
    ONLY when FROST is enabled — ``--no-frost`` runs meter-free."""

    def __init__(self, cfg, n_slots: int, edp_exponent: float):
        self.bus = EventBus()
        self.backend = RecordingBackend()
        self.device = PowerCappedDevice(TPU_V5E)
        self.cfg = cfg
        self.n_slots = n_slots
        self.meter = AnalyticDeviceMeter(self.device,
                                         decode_workload(cfg, n_slots))
        self.sampler = PowerSampler(
            {"gpu": self.meter, "cpu": CpuProcessMeter(),
             "dram": DramMeter(4, 16)},
            rate_hz=0.1, bus=self.bus, node_id="serve-0")
        self.cap_log = self.bus.tap(CapApplied)
        policy = QoSPolicy(policy_id=f"serve-ed{edp_exponent:g}p",
                           edp_exponent=edp_exponent) \
            if edp_exponent != BALANCED.edp_exponent else BALANCED
        self.profiler = OnlineCapProfiler(
            self.bus, self.backend, policy=policy, node_id="serve-0",
            model_id=cfg.name, steps_per_probe=1, hold_steps=8)
        self._step = 0

    def emit_chunk(self, n_useful: int, n_active: int, n_steps: int,
                   wall_s: float, tokens_scored: float = 1.0) -> float:
        """One fused chunk's telemetry: measured wall time + useful token
        count feed the profiler; the cap in force shapes the (simulated)
        accelerator's energy.  The workload is rebuilt at the chunk's live
        occupancy (``n_active`` slots) and charged for every step the
        device ran (incl. overrun/parked work) — the caller divides by the
        tokens it actually *served*.  ``tokens_scored`` is the speculative
        compute multiplier (K+1 verified tokens per sweep, accepted or
        not): energy must charge the FLOPs actually burned, which is how
        rejected drafts land in J/accepted-token as overhead.  Returns the
        chunk's J."""
        cap = self.backend.current_cap()     # honour latest cap command
        wl = decode_workload(self.cfg, n_active,
                             tokens_per_step=tokens_scored)
        self.meter.set_cap(cap)
        self.meter.set_workload(wl, busy=True)
        est = self.device.estimate(wl, cap)
        energy_j = est.energy_j * max(n_steps, 1)
        self.sampler.sample_once()           # -> PowerSampled on the bus
        self.bus.publish(StepDone(node_id="serve-0", step=self._step,
                                  duration_s=wall_s, samples=n_useful,
                                  energy_j=energy_j, model_id=self.cfg.name))
        self._step += 1
        return energy_j

    def summary(self):
        caps = self.cap_log
        probes = sum(1 for c in caps if c.reason == "probe")
        decisions = [c for c in caps if c.reason == "decision"]
        timeline = " -> ".join(f"{c.cap:.0%}({c.reason[0]})" for c in caps[:12])
        print(f"[frost-ctrl] {len(caps)} cap commands mid-run "
              f"({probes} amortised probes, {len(decisions)} decisions): "
              f"{timeline}{' ...' if len(caps) > 12 else ''}")
        if self.profiler.decision is not None:
            d = self.profiler.decision
            print(f"[frost-ctrl] serving cap {d.cap:.0%} of TDP "
                  f"(pred. energy saving {d.predicted_energy_saving:+.1%}, "
                  f"delay {d.predicted_delay_increase:+.1%}, "
                  f"fit {'accepted' if d.fit_accepted else 'fallback'})")
        self.profiler.close()


def run_batch(args, cfg, step_cfg, rules, params, frost: FrostPlane | None) -> int:
    """Static-batch baseline: batched prefill + fused ring decode chunks."""
    greedy = args.temperature <= 0.0
    plen = args.shared_prefix_len + args.prompt_len
    max_len = plen + args.gen
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, rules, max_len=max_len))
    chunk = max(1, args.decode_chunk)
    # ONE decode executable per run: the final ragged chunk is padded to
    # ``chunk`` and its overrun tokens discarded (the old path compiled a
    # second executable for the tail).  AOT-compiled so compile time never
    # lands in a chunk's measured duration.
    loop_fn = jax.jit(
        make_decode_loop(cfg, step_cfg, rules, chunk, greedy=greedy,
                         temperature=max(args.temperature, 1e-6)),
        donate_argnums=(1,))
    loop = None

    if args.shared_prefix_len > 0:
        # shared-system-prompt scenario: pooled heads + unique suffixes
        # (uniform total length, so the batch stacks)
        trace = batch_trace(args.requests, seed=args.seed,
                            vocab_size=cfg.vocab_size,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.gen,
                            n_codebooks=cfg.n_codebooks,
                            shared_prefix_len=args.shared_prefix_len,
                            prompt_pools=args.prompt_pools)
        prompts = np.stack([r.prompt for r in trace])
    else:
        data = TokenBatches(DataConfig(seed=args.seed,
                                       vocab_size=cfg.vocab_size,
                                       seq_len=args.prompt_len,
                                       global_batch=args.requests,
                                       n_codebooks=cfg.n_codebooks))
        prompts = data.batch(0)["inputs"]

    t0 = time.time()
    last_logits, cache = prefill(params, {"inputs": jnp.asarray(prompts)})
    if greedy:
        nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    else:
        key0 = jax.random.fold_in(jax.random.PRNGKey(args.sample_seed), 2**30)
        nxt = jax.random.categorical(
            key0, last_logits / args.temperature, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    spec = args.spec_k > 0
    drafter = dstate = None
    if spec:
        drafter = get_drafter(args.drafter, args.spec_k)
        loop_fn = jax.jit(
            make_speculative_decode_loop(
                cfg, step_cfg, rules, chunk, drafter=drafter, greedy=greedy,
                temperature=max(args.temperature, 1e-6)),
            donate_argnums=(1,))
        ds = drafter.init_state(args.requests)
        drafter.seed_batch(ds, np.asarray(prompts), np.asarray(nxt))
        dstate = {k: jnp.asarray(v) for k, v in ds.items()}

    generated = [np.asarray(nxt)[:, None]]   # token sampled from prefill
    tok = nxt[:, None]                       # (B, 1) or (B, 1, n_cb)
    remaining = args.gen - 1
    decode_energy_j = 0.0
    chunk_idx = 0
    n_spec_steps = n_spec_accepted = 0
    t_decode = 0.0                           # execution only, compile excluded
    while remaining > 0:
        args_loop = [params, cache, tok] + ([dstate] if spec else [])
        if not greedy:
            args_loop.append(jax.random.fold_in(
                jax.random.PRNGKey(args.sample_seed), chunk_idx))
        if loop is None:
            loop = loop_fn.lower(*args_loop).compile()
        t_c = time.perf_counter()
        if spec:
            toks, counts, cache, dstate = loop(*args_loop)
            toks = jax.block_until_ready(toks)
            counts = np.asarray(counts)       # uniform across B (ring lockstep)
            flat = np.concatenate(
                [np.asarray(toks)[:, s, :counts[0, s]]
                 for s in range(counts.shape[1])], axis=1)
            emitted = flat.shape[1]
            n_spec_steps += counts.shape[1]
            n_spec_accepted += int(counts[0].sum()) - counts.shape[1]
        else:
            toks, cache = loop(*args_loop)
            toks = jax.block_until_ready(toks)
            flat, emitted = np.asarray(toks), chunk
        wall = time.perf_counter() - t_c
        t_decode += wall
        keep = min(emitted, remaining)
        if frost is not None:
            # spec or not, a chunk is `chunk` cache sweeps; speculation
            # scores K+1 tokens per sweep (charged) and harvests 1..K+1
            decode_energy_j += frost.emit_chunk(
                keep * args.requests, args.requests, chunk, wall,
                tokens_scored=args.spec_k + 1 if spec else 1.0)
        generated.append(flat[:, :keep])
        # spec reassembles on host (ragged counts); the plain carry stays a
        # device-array slice — no H2D upload on the host-free loop
        tok = jnp.asarray(flat[:, -1:]) if spec else toks[:, -1:]
        remaining -= keep
        chunk_idx += 1
    toks_out = np.concatenate(generated, axis=1)

    # the first token came from prefill: tok/s and J/token charge only the
    # (gen - 1) * requests tokens the decode loop actually produced
    n_decoded = (args.gen - 1) * args.requests
    tok_per_s = n_decoded / max(t_decode, 1e-9)
    j_line = ""
    if frost is not None:
        j_line = f"; {decode_energy_j / max(n_decoded, 1):.3g} J/token analytic"
    spec_line = ""
    if spec and n_spec_steps:
        acc = n_spec_accepted / (n_spec_steps * args.spec_k)
        spec_line = (f", spec K={args.spec_k} acceptance {acc:.0%} "
                     f"({1 + n_spec_accepted / n_spec_steps:.2f} tok/sweep)")
    pol = step_cfg.kernel_policy
    print(f"[serve] prefill {args.requests}x{plen} in "
          f"{t_prefill*1e3:.0f} ms; decode {n_decoded} tokens in "
          f"{t_decode*1e3:.0f} ms ({tok_per_s:.0f} tok/s measured, "
          f"fused chunks of {chunk}, kv_splits {pol.kv_splits}, "
          f"decode_k_chunk {pol.decode_k_chunk}, "
          f"one executable{spec_line}{j_line})")
    print(f"[serve] sample continuation: {toks_out[0].ravel()[:16].tolist()}")
    return 0


def run_engine(args, cfg, step_cfg, rules, params,
               frost: FrostPlane | None) -> int:
    """Continuous batching: Poisson arrivals into the paged-KV engine."""
    greedy = args.temperature <= 0.0
    max_len = args.shared_prefix_len + args.prompt_len + args.gen
    recompute_j = None
    if args.host_tier and frost is not None:
        # price page recompute at the analytic one-sequence sweep cost per
        # token under full power — the demote-vs-evict rule then compares a
        # page's D2H+H2D round trip against re-prefilling its rows
        recompute_j = frost.device.estimate(
            decode_workload(cfg, 1), 1.0).energy_j
    ecfg = EngineConfig(n_slots=args.n_slots, page_size=args.page_size,
                        max_len=max_len, decode_chunk=max(1, args.decode_chunk),
                        n_pages=args.n_pages, greedy=greedy,
                        temperature=max(args.temperature, 1e-6),
                        sample_seed=args.sample_seed,
                        spec_k=max(0, args.spec_k), drafter=args.drafter,
                        prefix_cache=not args.no_prefix_cache,
                        prefill_chunk=max(1, args.prefill_chunk),
                        preempt=not args.no_preempt,
                        max_skip=max(0, args.max_skip),
                        kv_splits=_parse_kv_splits(args.kv_splits),
                        decode_k_chunk=max(1, args.decode_k_chunk),
                        kv_dtype=args.kv_dtype,
                        host_tier=args.host_tier,
                        host_pages=args.host_pages,
                        transfer_j_per_byte=args.transfer_j_per_byte,
                        recompute_j_per_token=recompute_j)
    # effective tokens per slot-step: 1.0 plain; under speculation the
    # on_chunk hook keeps a running estimate (accepted + bonus per sweep) so
    # the admission policy prices occupancy at the throughput actually
    # delivered, not one token per sweep
    eff = {"tps": 1.0}

    def on_chunk(s):
        if s.n_active and ecfg.spec_k:
            tps = s.tokens_kept / max(s.n_active * ecfg.decode_chunk, 1)
            eff["tps"] = 0.5 * eff["tps"] + 0.5 * max(tps, 1.0)
        if frost is None:
            return None
        return frost.emit_chunk(s.tokens_kept, s.n_active,
                                ecfg.decode_chunk, s.wall_s,
                                tokens_scored=ecfg.spec_k + 1)

    pref = {"avoided_j": 0.0}

    def on_prefill(n_computed, n_saved):
        # prefill compute feeds the same J/token ledger as decode chunks;
        # tokens the prefix cache restored are joules never drawn — priced
        # at the analytic one-sequence sweep cost under the cap in force
        if frost is None:
            return None
        cap = frost.backend.current_cap()
        e_tok = frost.device.estimate(decode_workload(cfg, 1), cap).energy_j
        pref["avoided_j"] += e_tok * n_saved
        return e_tok * n_computed

    admission = None
    if args.power_budget > 0:
        device = frost.device if frost is not None else PowerCappedDevice(TPU_V5E)
        admission = EnergyAwareAdmission(
            device, lambda n: decode_workload(cfg, n, tokens_per_step=eff["tps"]),
            args.power_budget,
            backend=frost.backend if frost is not None else None)

    p_lo = min(max(4, args.prompt_len // 2), args.prompt_len)
    g_lo = min(max(2, args.gen // 2), args.gen)
    trace = poisson_trace(
        args.requests, rate_per_step=args.arrival_rate, seed=args.seed,
        vocab_size=cfg.vocab_size,
        prompt_len=(p_lo, args.prompt_len),
        max_new_tokens=(g_lo, args.gen),
        n_codebooks=cfg.n_codebooks, eos_id=args.eos_id,
        shared_prefix_len=args.shared_prefix_len,
        prompt_pools=args.prompt_pools)
    # -- chaos / fault-tolerance wiring (docs/fault_tolerance.md) ---------
    injector = None
    if args.chaos:
        injector = FaultInjector.from_spec(args.chaos, seed=args.chaos_seed)
    snapshot_dir = args.snapshot_dir
    if snapshot_dir is None and injector is not None and \
            any(ev.kind == "engine_crash" for ev in injector.events):
        # a crash without a snapshot dir would lose work — default to a
        # throwaway dir so the drill recovers instead of dying
        snapshot_dir = tempfile.mkdtemp(prefix="serve_snap_")
        print(f"[chaos] engine_crash armed; snapshots -> {snapshot_dir}")
    snapshot_every = args.snapshot_every if snapshot_dir is not None else 0

    supervisor = ServingSupervisor(bus=frost.bus if frost is not None
                                   else None, node_id="serve-0")
    cbus = ChaosBus(frost.bus) if frost is not None else None
    if cbus is not None:
        frost.bus = cbus       # emit_chunk publishes through the chaos shim

    def on_fault(ev):
        # bus_drop / bus_delay disturb the telemetry transport, not the
        # engine: swallow or hold the next N publishes on the control bus
        if cbus is None:
            return
        if ev.kind == "bus_drop":
            cbus.drop_next(max(1, ev.duration))
        elif ev.kind == "bus_delay":
            cbus.delay_next(max(1, ev.duration))

    eng_kwargs = dict(step_cfg=step_cfg, rules=rules, on_chunk=on_chunk,
                      on_prefill=on_prefill, admission=admission,
                      injector=injector,
                      on_heartbeat=supervisor.on_heartbeat, on_fault=on_fault,
                      snapshot_every=snapshot_every)
    engine = ServeEngine(cfg, ecfg, params, snapshot_dir=snapshot_dir,
                         **eng_kwargs)
    restarts = 0
    while True:
        try:
            rep = engine.resume() if restarts else engine.run(trace)
            break
        except EngineCrash as crash:
            restarts += 1
            if snapshot_dir is None or restarts > args.max_restarts:
                raise
            print(f"[chaos] engine crashed at step {crash.step}; "
                  f"restoring from {snapshot_dir} "
                  f"(restart {restarts}/{args.max_restarts})")
            engine = ServeEngine.restore(cfg, ecfg, params, snapshot_dir,
                                         **eng_kwargs)
    if cbus is not None:
        cbus.flush()           # deliver anything a bus_delay still holds

    lat = rep.latency_percentiles((50, 95))
    waits = [r.wait_steps for r in rep.results if r.admit_step >= 0]
    print(f"[serve] engine: {len(rep.results)} requests over {rep.n_chunks} "
          f"chunks of {ecfg.decode_chunk} ({args.n_slots} slots, "
          f"page_size {args.page_size}, kv_splits {ecfg.kv_splits}, "
          f"decode_k_chunk {ecfg.decode_k_chunk}, "
          f"occupancy {rep.occupancy:.0%})")
    j_name = "J/accepted-token" if ecfg.spec_k else \
        "J/token (occupied slots only)"
    j_line = f", {rep.j_per_token:.3g} {j_name}" if frost is not None else ""
    print(f"[serve] decode {rep.tokens_kept} useful / {rep.tokens_computed} "
          f"computed tokens in {rep.decode_wall_s*1e3:.0f} ms "
          f"({rep.tok_per_s:.0f} tok/s measured{j_line})")
    if ecfg.spec_k:
        print(f"[serve] speculative K={ecfg.spec_k} ({ecfg.drafter}): "
              f"acceptance {rep.acceptance_rate:.0%}, "
              f"{rep.tokens_per_step:.2f} tokens/slot-sweep "
              f"(admission sees {eff['tps']:.2f}x effective tok/s)")
    if ecfg.prefix_cache:
        j_avoid = ""
        if frost is not None and pref["avoided_j"] > 0:
            j_avoid = (f", ~{pref['avoided_j']:.3g} J prefill avoided "
                       "(modelled, in the J/token ledger)")
        print(f"[serve] prefix cache: {rep.prefix_hit_rate:.0%} of "
              f"{rep.prompt_tokens} prompt tokens restored "
              f"({rep.prefill_tokens_saved} saved), "
              f"{rep.n_preemptions} preemptions{j_avoid}")
    if ecfg.host_tier:
        print(f"[serve] kv tier: {engine.kv_dtype} pages, "
              f"{rep.n_demotions} paged out / {rep.n_promotions} paged in, "
              f"transfer {rep.transfer_j:.3g} J (in the J/token ledger)")
    print(f"[serve] latency p50 {lat[50]:.0f} / p95 {lat[95]:.0f} steps; "
          f"queue wait mean {np.mean(waits):.1f} steps"
          if waits else "[serve] nothing admitted")
    if injector is not None:
        kinds = ", ".join(f"{ev.kind}@{ev.step}" for ev in injector.log)
        print(f"[chaos] {rep.n_faults_injected} faults injected ({kinds}); "
              f"{rep.n_restores} restores, {rep.requeued_requests} requests "
              f"requeued, {rep.degraded_steps} degraded steps, "
              f"{rep.n_pages_quarantined} pages quarantined")
        if cbus is not None and (cbus.n_dropped or cbus.n_delayed):
            print(f"[chaos] telemetry: {cbus.n_dropped} publishes dropped, "
                  f"{cbus.n_delayed} delayed (flushed at exit)")
    derate = supervisor.workers[supervisor.node_id].derate
    if supervisor.n_derates_published:
        print(f"[supervisor] derate estimate {derate:.0%} "
              f"({supervisor.n_derates_published} NodeDerated published)")
    for r in rep.results[:4]:
        print(f"[serve]   rid={r.rid} L={r.prompt_len} "
              f"gen={r.n_tokens}/{r.max_new_tokens} wait={r.wait_steps} "
              f"lat={r.latency_steps} fin={r.finish_reason}"
              + (f" J/tok={r.j_per_token:.3g}" if frost is not None else ""))
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per fused lax.scan decode chunk")
    ap.add_argument("--kv-splits", type=str, default="auto",
                    help="two-stage split-KV decode sweep: 'auto' picks by "
                         "the ops.choose_kv_splits occupancy model, an int "
                         "forces that split count (1 = single-stage sweep)")
    ap.add_argument("--decode-k-chunk", type=int, default=256,
                    help="split-K block (keys per grid step) for the ring "
                         "decode/verify kernels")
    ap.add_argument("--traffic", choices=("batch", "poisson"), default="batch",
                    help="batch: static fixed-batch baseline; poisson: "
                         "continuous-batching engine under Poisson arrivals")
    ap.add_argument("--arrival-rate", type=float, default=0.25,
                    help="poisson arrivals per decode step")
    ap.add_argument("--n-slots", type=int, default=4,
                    help="decode slots (engine batch dimension)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV-cache page size (tokens per block)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV page pool size (default: fully provisioned; "
                         "smaller pools exercise preemption/requeue)")
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="KV page storage: int8 packs pages with per-row "
                         "fp32 scales, dequant fused into the decode sweeps "
                         "(dense-GQA families; others warn and fall back)")
    ap.add_argument("--host-tier", action="store_true",
                    help="page cold prefix-cache pages out to a host-memory "
                         "tier instead of dropping them (poisson mode)")
    ap.add_argument("--host-pages", type=int, default=None,
                    help="host-tier page budget (default: unbounded)")
    ap.add_argument("--transfer-j-per-byte", type=float, default=1e-9,
                    help="modelled D2H/H2D transfer energy, J per byte, "
                         "charged into the serving J/token ledger")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help=">0: every prompt = pooled shared head of this "
                         "length + unique suffix (both traffic modes)")
    ap.add_argument("--prompt-pools", type=int, default=1,
                    help="number of distinct shared prefixes to draw from")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prompt-prefix page sharing in the engine")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="suffix tokens per chunked-prefill verify sweep")
    ap.add_argument("--no-preempt", action="store_true",
                    help="reserve the whole context at admission instead "
                         "of lazy pages + preemption/requeue")
    ap.add_argument("--max-skip", type=int, default=2,
                    help="head-of-line skip-ahead window when the queue "
                         "head cannot get pages (0 = strict FIFO)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help=">0: speculative decoding — verify K drafts + 1 "
                         "bonus token per cache sweep (both traffic modes)")
    ap.add_argument("--drafter", choices=("ngram", "repeat"), default="ngram",
                    help="self-drafter for --spec-k (ngram = prompt-lookup)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples with this temperature")
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="free a slot early when this token is sampled")
    ap.add_argument("--power-budget", type=float, default=0.0,
                    help="W; >0 gates admission on predicted board draw")
    ap.add_argument("--chaos", type=str, default="",
                    help="fault schedule 'kind@step[:duration[:arg]],...' "
                         "on the engine clock (poisson mode), e.g. "
                         "'slot_crash@20,engine_crash@40,"
                         "emergency_cap@60:16:0.5'")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the fault injector's RNG (corruption "
                         "site choice)")
    ap.add_argument("--snapshot-dir", type=str, default=None,
                    help="engine snapshot directory (crash recovery); "
                         "auto tempdir when --chaos arms an engine_crash")
    ap.add_argument("--snapshot-every", type=int, default=4,
                    help="snapshot every N decode chunks (needs "
                         "--snapshot-dir or an armed engine_crash)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="crash-restore attempts before giving up")
    ap.add_argument("--no-frost", action="store_true",
                    help="disable the FROST control plane (no sampler, "
                         "meters, or bus are even built)")
    ap.add_argument("--edp-exponent", type=float, default=2.0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    step_cfg = with_decode_policy(StepConfig(remat="none"),
                                  kv_splits=_parse_kv_splits(args.kv_splits),
                                  decode_k_chunk=max(1, args.decode_k_chunk))
    mesh = make_host_mesh()
    rules = build_rules(cfg, mesh) if mesh.devices.size > 1 else None
    params, _ = tfm.init_lm(jax.random.PRNGKey(args.seed), cfg)

    n_par = args.n_slots if args.traffic == "poisson" else args.requests
    frost = None if args.no_frost else FrostPlane(cfg, n_par, args.edp_exponent)

    if args.traffic == "poisson":
        blockers = tfm.paged_cache_blockers(cfg)
        if blockers:
            # the capability router names the specific blocking feature;
            # today the tuple is empty for every family in the zoo, but the
            # seam keeps future configs serving (ring batch) instead of dying
            ops.warn_paged_fallback(cfg.name, blockers[0])
            rc = run_batch(args, cfg, step_cfg, rules, params, frost)
        else:
            rc = run_engine(args, cfg, step_cfg, rules, params, frost)
    else:
        rc = run_batch(args, cfg, step_cfg, rules, params, frost)
    if frost is not None:
        frost.summary()
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
