"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 20 --frost

Wires together: config registry -> data pipeline -> sharded train step ->
FROST control plane (batch profile warm-starts an online profiler that
keeps retuning the cap from streamed step telemetry) -> FT supervisor
(heartbeats, checkpoint/restart, straggler power-shifting) -> telemetry
ledger.  On this CPU container use --smoke (reduced configs); the full
configs are exercised through the dry-run.

Every train step publishes ``StepDone`` on the control-plane bus and reads
the enforcement backend before the next step, so cap commands issued by the
online profiler (or a cluster coordinator) take effect mid-run — the
paper's Fig 1 loop, not a one-shot offline probe.

Real-TPU deployments additionally want the XLA latency-hiding scheduler:
    LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true"
    XLA_FLAGS="--xla_tpu_enable_latency_hiding_scheduler=true"
(recorded here, inert on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.control import CapApplied, EventBus, StepDone
from repro.control.online import OnlineCapProfiler
from repro.core import (CapProfiler, PowerCappedDevice, QoSPolicy, TPU_V5E,
                        WorkloadProfile)
from repro.core.profiler import RecordingBackend
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenBatches
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from repro.runtime.fault import Supervisor, SupervisorConfig
from repro.runtime.sharding import build_rules
from repro.runtime.steps import StepConfig, init_train_state, make_train_step
from repro.telemetry.meters import AnalyticDeviceMeter, CpuProcessMeter, DramMeter
from repro.telemetry.sampler import PowerSampler


def profile_cap_for_step(cfg: ModelConfig, flops: float, bytes_hbm: float,
                         coll: float, policy: QoSPolicy, *,
                         bus=None, backend=None):
    """FROST batch pass: given the compiled step's roofline terms, pick the
    cap.  Returns (decision, workload, device) so the online profiler can
    warm-start from the same artefacts."""
    wl = WorkloadProfile(name=cfg.name, flops_per_step=flops,
                         hbm_bytes_per_step=bytes_hbm,
                         collective_bytes_per_step=coll,
                         samples_per_step=1)
    dev = PowerCappedDevice(TPU_V5E)

    class _W:                                   # Workload protocol adapter
        def probe(self, cap, duration_s):
            return dev.probe(wl, cap, duration_s)

    prof = CapProfiler(_W(), policy=policy, probe_seconds=30.0,
                       bus=bus, backend=backend, node_id="node-0")
    return prof.run(), wl, dev


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--frost", action="store_true",
                    help="run the FROST control plane (batch profile warm-"
                         "starts an online retuner over the step stream)")
    ap.add_argument("--edp-exponent", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    step_cfg = StepConfig(
        n_micro=args.n_micro, remat="none",
        optimizer=OptimizerConfig(learning_rate=args.lr,
                                  warmup_steps=max(2, args.steps // 10),
                                  total_steps=args.steps))

    mesh = make_host_mesh()
    rules = build_rules(cfg, mesh) if mesh.devices.size > 1 else None

    key = jax.random.PRNGKey(args.seed)
    state, axes = init_train_state(key, cfg, step_cfg)
    train_step = jax.jit(make_train_step(cfg, step_cfg, rules),
                         donate_argnums=(0,))

    data = TokenBatches(DataConfig(
        seed=args.seed, vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, n_codebooks=cfg.n_codebooks))

    # -- FROST control plane (paper Sec III-C + Fig 1 loop) --------------------
    bus = EventBus()
    backend = RecordingBackend()
    cap_log = bus.tap(CapApplied)        # lossless cap-command accounting
    frost_wl = frost_dev = online = gpu_meter = None
    if args.frost:
        policy = QoSPolicy(policy_id=f"train-ed{args.edp_exponent:g}p",
                           edp_exponent=args.edp_exponent)
        # derive roofline terms from one compiled step
        from repro.launch import hloparse
        lowered = train_step.lower(state, data.batch(0))
        compiled = lowered.compile()
        h = hloparse.analyze(compiled.as_text())
        ca = hloparse.xla_cost(compiled)
        decision, frost_wl, frost_dev = profile_cap_for_step(
            cfg, h["dot_flops"], float(ca.get("bytes accessed", 0.0)),
            h["collective_bytes"], policy, bus=bus, backend=backend)
        print(f"[frost] selected cap = {decision.cap:.0%} "
              f"(pred. energy saving {decision.predicted_energy_saving:+.1%}, "
              f"delay {decision.predicted_delay_increase:+.1%}, "
              f"fit rmse {decision.fit.rel_rmse:.3%})")
        # warm-start the online retuner: no further dedicated probe windows —
        # refreshes are amortised across live train steps
        gpu_meter = AnalyticDeviceMeter(frost_dev, frost_wl, cap=decision.cap)
        gpu_meter.set_workload(frost_wl, busy=True)
        online = OnlineCapProfiler(bus, backend, policy=policy,
                                   node_id="node-0", model_id=cfg.name,
                                   steps_per_probe=2, hold_steps=16,
                                   warm_start=decision)

    meters = {"cpu": CpuProcessMeter(), "dram": DramMeter(4, 16)}
    if gpu_meter is not None:
        meters["gpu"] = gpu_meter
    sampler = PowerSampler(meters, rate_hz=0.1, bus=bus, node_id="node-0")

    # -- supervised run ----------------------------------------------------------
    ckpt = CheckpointManager(args.ckpt_dir, keep=2, save_async=True)
    ckpt.save(state, 0)                    # recovery floor before step 1
    sup = Supervisor(
        SupervisorConfig(checkpoint_every=args.ckpt_every),
        save_fn=lambda s, i: ckpt.save(s, i),
        restore_fn=lambda: (ckpt.restore(state), ckpt.latest_step() or 0))
    sup.register("node-0")

    step_no = {"i": 0}

    def instrumented_step(state, batch):
        """The step loop as a control-plane producer: run the jitted step,
        honour whatever cap is currently enforced, publish StepDone."""
        state, metrics = train_step(state, batch)
        cap = backend.current_cap()            # cap commands land mid-run
        if frost_dev is not None:
            gpu_meter.set_cap(cap)
            est = frost_dev.estimate(frost_wl, cap)
            duration_s, energy_j = est.step_time_s, est.energy_j
        else:
            duration_s, energy_j = 0.0, 0.0
        i = step_no["i"] = step_no["i"] + 1
        if duration_s > 0:
            # samples must match the profile workload's samples_per_step (1):
            # the online drift check compares time/SAMPLE against the batch
            # profile's expectation, so mixed units read as huge fake drift.
            bus.publish(StepDone(node_id="node-0", step=i,
                                 duration_s=duration_s,
                                 samples=frost_wl.samples_per_step,
                                 energy_j=energy_j, model_id=cfg.name))
        return state, metrics

    batches = (data.batch(i) for i in range(args.steps))
    t0 = time.time()
    with sampler:
        state, report = sup.run(instrumented_step, state, batches)
    dt = time.time() - t0
    losses = [h["loss"] for h in report["history"]]
    print(f"[train] {report['final_step']} steps in {dt:.1f}s "
          f"({dt/max(report['final_step'],1):.3f}s/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if online is not None:
        print(f"[frost-ctrl] {len(cap_log)} cap commands over the run; "
              f"online refits={online.n_refits} "
              f"cap now {backend.current_cap():.0%}")
        online.close()
    ckpt.wait()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
