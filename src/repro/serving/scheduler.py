"""Request queue + slot scheduler for the continuous-batching engine.

The engine owns a fixed grid of ``n_slots`` decode slots (the jitted loop's
batch dimension never changes — one AOT executable for every occupancy
pattern).  The scheduler's job is to map a stream of ragged requests onto
those slots: FIFO admission as slots and KV pages free up, an optional
*admission hook* (energy-aware policies plug in here), and bookkeeping of
which slot runs which request.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from repro.serving.paged_kv import PagedKVCache
from repro.serving.request import Request


class RequestQueue:
    """Arrival-ordered FIFO with a virtual-step clock."""

    def __init__(self, requests: list[Request]):
        self._pending = deque(sorted(requests, key=lambda r:
                                     (r.arrival_step, r.rid)))

    def __len__(self) -> int:
        return len(self._pending)

    def next_arrival(self) -> int | None:
        return self._pending[0].arrival_step if self._pending else None

    def peek_ready(self, now_step: int) -> Request | None:
        if self._pending and self._pending[0].arrival_step <= now_step:
            return self._pending[0]
        return None

    def pop(self) -> Request:
        return self._pending.popleft()


@dataclasses.dataclass
class SlotState:
    """A live request bound to a decode slot."""
    request: Request
    remaining: int                # decode-loop tokens still wanted
    next_token: object            # host-side (1,) or (1, n_cb) np token
    finished: bool = False


# admission hook: (request, n_active_after_admit) -> admit?  Policies that
# need device state (cap in force, power budget) close over it — see
# ``engine.EnergyAwareAdmission``.
AdmissionHook = Callable[[Request, int], bool]


class Scheduler:
    """Admits ragged requests into fixed decode slots, mid-stream.

    ``poll`` is called between chunks: it binds as many ready requests as
    slots, pages, and the admission hook allow.  Freeing (EOS / token
    budget) is driven by the engine at harvest time via ``finish``.
    """

    def __init__(self, n_slots: int, kv: PagedKVCache,
                 admission: AdmissionHook | None = None):
        self.n_slots = n_slots
        self.kv = kv
        self.admission = admission
        self.slots: list[SlotState | None] = [None] * n_slots
        self._free = deque(range(n_slots))

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def poll(self, queue: RequestQueue, now_step: int) -> list[tuple[int, Request]]:
        """Admit ready requests into free slots; returns (slot, request)
        pairs the engine must prefill-join this cycle."""
        joins: list[tuple[int, Request]] = []
        while self._free:
            req = queue.peek_ready(now_step)
            if req is None:
                break
            # pages must cover every position a kept token attends to:
            # prompt + max_new - 1 (the last fed token's write)
            ctx_tokens = req.prompt_len + req.max_new_tokens - 1
            if not self.kv.can_admit(ctx_tokens):
                break                        # FIFO: no overtaking on pages
            if self.admission is not None and \
                    not self.admission(req, self.n_active + 1):
                break
            queue.pop()
            slot = self._free.popleft()
            self.kv.admit(slot, ctx_tokens)
            self.slots[slot] = SlotState(request=req,
                                         remaining=req.max_new_tokens - 1,
                                         next_token=None)
            joins.append((slot, req))
        return joins

    def finish(self, slot: int) -> None:
        """Free the slot and its pages (called at harvest on EOS/budget)."""
        if self.slots[slot] is None:
            raise ValueError(f"slot {slot} is not active")
        self.kv.release(slot)
        self.slots[slot] = None
        self._free.append(slot)
