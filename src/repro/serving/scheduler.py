"""Request queue + slot scheduler for the continuous-batching engine.

The engine owns a fixed grid of ``n_slots`` decode slots (the jitted loop's
batch dimension never changes — one AOT executable for every occupancy
pattern).  The scheduler's job is to map a stream of ragged requests onto
those slots: FIFO admission as slots and KV pages free up (with a bounded
*skip-ahead* window so a page-starved head request cannot indefinitely
starve smaller requests behind it), an optional *admission hook*
(energy-aware policies plug in here), and bookkeeping of which slot runs
which request.

Two admission shapes share this class:

  * **reserve** (``lazy=False``) — a request is admitted only when pages
    cover its whole context (prompt + generation budget); nothing can run
    out mid-decode.  This is the pre-preemption engine, kept as the
    baseline.
  * **lazy** (``lazy=True``) — admission covers only the prompt; decode
    pages are allocated chunk-by-chunk by the engine (``PagedKVCache
    .ensure``), and when the pool runs dry the engine preempts the
    lowest-priority slot and re-queues its request (generated tokens
    folded into the prompt, which the prefix cache then mostly restores).
    This replaces the old hard admission stall with graceful overcommit.

With ``prefix=True`` the page-fit check credits pages the prefix cache
already holds for the request's prompt (``can_admit_with_prefix``), so
shared-prompt traffic admits at higher concurrency for the same pool.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Callable

from repro.serving.paged_kv import PagedKVCache
from repro.serving.request import Request


class RequestQueue:
    """Arrival-ordered queue with a virtual-step clock.  Supports pushing
    re-queued (preempted) requests mid-run and popping non-head entries
    for the bounded skip-ahead."""

    def __init__(self, requests: list[Request]):
        self._pending = sorted(requests,
                               key=lambda r: (r.arrival_step, r.rid))

    def __len__(self) -> int:
        return len(self._pending)

    def next_arrival(self) -> int | None:
        return self._pending[0].arrival_step if self._pending else None

    def peek_ready(self, now_step: int) -> Request | None:
        if self._pending and self._pending[0].arrival_step <= now_step:
            return self._pending[0]
        return None

    def ready(self, now_step: int):
        """(index, request) pairs that have arrived, in queue order."""
        for i, req in enumerate(self._pending):
            if req.arrival_step > now_step:
                break
            yield i, req

    def pop(self) -> Request:
        return self._pending.pop(0)

    def pop_at(self, index: int) -> Request:
        return self._pending.pop(index)

    def pending(self) -> list[Request]:
        """Queued requests in arrival order (snapshot/introspection)."""
        return list(self._pending)

    def push(self, request: Request) -> None:
        """Insert a (re-queued) request in arrival order."""
        keys = [(r.arrival_step, r.rid) for r in self._pending]
        self._pending.insert(
            bisect.bisect(keys, (request.arrival_step, request.rid)),
            request)


@dataclasses.dataclass
class SlotState:
    """A live request bound to a decode slot."""
    request: Request
    remaining: int                # decode-loop tokens still wanted
    next_token: object            # host-side (1,) or (1, n_cb) np token
    finished: bool = False
    seq: int = 0                  # admission order (preemption tie-break)
    tok_start: int = 0            # result-token index where this bind began


# admission hook: (request, n_active_after_admit) -> admit?  Policies that
# need device state (cap in force, power budget) close over it — see
# ``engine.EnergyAwareAdmission``.
AdmissionHook = Callable[[Request, int], bool]


class Scheduler:
    """Admits ragged requests into fixed decode slots, mid-stream.

    ``poll`` is called between chunks: it binds as many ready requests as
    slots, pages, and the admission hook allow.  Freeing (EOS / token
    budget / preemption) is driven by the engine at harvest time via
    ``finish``.
    """

    def __init__(self, n_slots: int, kv: PagedKVCache,
                 admission: AdmissionHook | None = None, *,
                 max_skip: int = 0, lazy: bool = False,
                 prefix: bool = False):
        self.n_slots = n_slots
        self.kv = kv
        self.admission = admission
        self.max_skip = int(max_skip)
        self.lazy = lazy
        self.prefix = prefix
        self.slots: list[SlotState | None] = [None] * n_slots
        self._free = deque(range(n_slots))
        self._seq = 0

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _alloc_tokens(self, req: Request) -> int:
        # reserve mode: pages must cover every position a kept token
        # attends to — prompt + max_new - 1 (the last fed token's write).
        # lazy mode: the prompt only; the engine grows per chunk.
        if self.lazy:
            return req.prompt_len
        return req.prompt_len + req.max_new_tokens - 1

    def _fits(self, req: Request) -> bool:
        n = self._alloc_tokens(req)
        if self.prefix:
            return self.kv.can_admit_with_prefix(req.prompt, n)
        return self.kv.can_admit(n)

    def poll(self, queue: RequestQueue, now_step: int):
        """Admit ready requests into free slots; returns (slot, request,
        matched_len, copy_spec) tuples the engine must prefill-join this
        cycle (``matched_len``/``copy_spec`` are 0/None without prefix
        sharing).

        Admission is FIFO with a bounded skip-ahead: when the head cannot
        get pages, up to ``max_skip`` ready requests behind it are tried
        (smaller requests can use pages the head cannot) — but an
        admission-hook refusal still stops the poll cold, since the hook
        prices *occupancy* and would refuse every candidate alike."""
        joins = []
        while self._free:
            picked = None
            for tried, (idx, req) in enumerate(queue.ready(now_step)):
                if tried > self.max_skip:
                    break
                if self.admission is not None and \
                        not self.admission(req, self.n_active + 1):
                    break
                if self._fits(req):
                    picked = idx
                    break
            if picked is None:
                break
            req = queue.pop_at(picked)
            slot = self._free.popleft()
            matched, copy = 0, None
            if self.prefix:
                matched, copy = self.kv.admit_with_prefix(
                    slot, req.prompt, self._alloc_tokens(req))
            else:
                self.kv.admit(slot, self._alloc_tokens(req))
            self.slots[slot] = SlotState(request=req,
                                         remaining=req.max_new_tokens - 1,
                                         next_token=None, seq=self._seq)
            self._seq += 1
            joins.append((slot, req, matched, copy))
        return joins

    def victim(self) -> int | None:
        """The slot to preempt when pages run dry: lowest priority first,
        most-recently-admitted among ties (LIFO keeps the head of the
        line making progress).  The engine handles the case where the
        victim is the slot doing the asking (self-preempt or raise)."""
        cands = [(s.request.priority, -s.seq, i)
                 for i, s in enumerate(self.slots) if s is not None]
        if not cands:
            return None
        return min(cands)[2]

    def finish(self, slot: int) -> None:
        """Free the slot and its page holds (called at harvest on
        EOS/budget, and by the engine on preemption)."""
        if self.slots[slot] is None:
            raise ValueError(f"slot {slot} is not active")
        self.kv.release(slot)
        self.slots[slot] = None
        self._free.append(slot)
