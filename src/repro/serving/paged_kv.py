"""Paged KV-cache manager: block tables + free list over shared page pools.

Replaces per-request ring buffers with a pool of fixed-size pages shared by
every decode slot (vLLM's PagedAttention layout, collapsed to the needs of
this engine).  The device side — per-unit pools of shape ``(n_units,
n_pages, page_size, Hkv, hd)`` plus per-slot ``block_tables``/``pos`` —
comes from :func:`repro.models.transformer.init_paged_cache`; this class
owns the *host* side: which physical page backs which logical block of
which slot, and which pages are free.

Invariants the decode path relies on:

  * pages 0..n_slots-1 are reserved per-slot *scratch* pages; a free slot's
    whole table row points at its scratch page, so parked slots can keep
    executing (write + attend on scratch garbage, output discarded) without
    any validity branch in the jitted loop;
  * a live slot's table rows beyond its allocation also point at scratch,
    so within-chunk overrun past a request's budget stays contained;
  * distinct slots never share a non-scratch page — the per-layer scatter
    in ``gqa_decode_paged`` therefore never sees duplicate rows across the
    batch.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.models import transformer as tfm


class PagedKVCache:
    """Host-side page allocator for the paged decode cache."""

    def __init__(self, cfg, *, n_slots: int, page_size: int, max_len: int,
                 n_pages: int | None = None, dtype: str = "bfloat16"):
        if not tfm.supports_paged_cache(cfg):
            raise ValueError(f"{cfg.name}: paged KV cache supports dense "
                             "GQA families only (no ssm/mla/window/hybrid)")
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.max_blocks = -(-self.max_len // self.page_size)
        if n_pages is None:
            # full provisioning: every slot can hold max_len, plus scratch
            n_pages = self.n_slots * self.max_blocks + self.n_slots
        self.n_pages = int(n_pages)
        self.dtype = dtype
        # scratch page s backs every unallocated block of slot s
        self.tables = np.arange(self.n_slots, dtype=np.int32)[:, None].repeat(
            self.max_blocks, axis=1)
        self.free: deque[int] = deque(range(self.n_slots, self.n_pages))
        self.allocated: dict[int, list[int]] = {}   # slot -> pages

    # -- device side --------------------------------------------------------
    def make_cache(self):
        """Fresh zero-filled device cache pytree matching this manager."""
        return tfm.init_paged_cache(self.cfg, self.n_slots, self.n_pages,
                                    self.page_size, self.max_blocks,
                                    dtype=self.dtype)

    # -- allocation ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 1) // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self.free)

    def admit(self, slot: int, n_tokens: int) -> list[int]:
        """Allocate pages covering ``n_tokens`` context positions for
        ``slot`` and point its table's leading blocks at them."""
        if slot in self.allocated:
            raise ValueError(f"slot {slot} already holds an allocation")
        need = self.pages_for(n_tokens)
        if need > len(self.free):
            raise ValueError(f"slot {slot}: {need} pages needed, "
                             f"{len(self.free)} free")
        if need > self.max_blocks:
            raise ValueError(f"request needs {need} blocks > table width "
                             f"{self.max_blocks} (max_len {self.max_len})")
        pages = [self.free.popleft() for _ in range(need)]
        self.tables[slot, :] = slot                 # park the tail on scratch
        self.tables[slot, :need] = pages
        self.allocated[slot] = pages
        return pages

    def release(self, slot: int) -> None:
        """Return ``slot``'s pages to the free list and park it."""
        pages = self.allocated.pop(slot, [])
        self.free.extend(pages)
        self.tables[slot, :] = slot

    # -- injection helper ---------------------------------------------------
    def inject_rows(self, slot: int, bucket_len: int, n_valid: int) -> np.ndarray:
        """Flat pool-row destinations for copying a prefill cache (padded to
        ``bucket_len``) into ``slot``'s pages.  Rows past ``n_valid`` (the
        real prompt length) map out of bounds and are dropped by the
        ``mode="drop"`` scatter."""
        rows = np.empty((bucket_len,), np.int32)
        for i in range(bucket_len):
            if i < n_valid:
                page = self.tables[slot, i // self.page_size]
                rows[i] = page * self.page_size + i % self.page_size
            else:
                rows[i] = self.n_pages * self.page_size    # dropped
        return rows

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def occupancy(self) -> float:
        """Fraction of non-scratch pages currently allocated."""
        usable = self.n_pages - self.n_slots
        return 1.0 - len(self.free) / max(usable, 1)
