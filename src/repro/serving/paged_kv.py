"""Paged KV-cache manager: ref-counted pages + prefix sharing + free list.

Replaces per-request ring buffers with a pool of fixed-size pages shared by
every decode slot (vLLM's PagedAttention layout, collapsed to the needs of
this engine).  The device side — per-unit pools of shape ``(n_units,
n_pages, page_size, Hkv, hd)`` plus per-slot ``block_tables``/``pos`` —
comes from :func:`repro.models.transformer.init_paged_cache`; this class
owns the *host* side: which physical page backs which logical block of
which slot, which pages are free, and — new in this layer — which pages
hold a **cached prompt prefix** that future requests can map read-only
instead of recomputing.

Prefix sharing
--------------
Pages are immutable once full, and a page's KV rows depend only on the
token ids of the whole prefix up to and including that page (RoPE is
applied at absolute positions, and the layout is linear: block ``j`` holds
positions ``[j*ps, (j+1)*ps)``).  So a trie keyed on page-sized token
chunks indexes every cached prefix: ``admit_with_prefix`` walks it and maps
the longest cached prefix onto shared read-only pages (refcount + 1 each),
allocating private pages only for the uncached suffix.  When the match
ends inside a page (the common system prompt is rarely page-aligned), the
shared page cannot be mapped directly — the suffix prefill would write
into it — so the manager emits a **copy-on-write** spec: the engine copies
the matched rows into the slot's private page device-side and only then
writes the suffix behind them.

The trie itself holds one reference per indexed page, so a released
request's prefix pages *survive* until evicted — this is what makes
preemption cheap: a preempted request re-queued with its generated tokens
folded into the prompt finds nearly all of its pages still cached and
prefills only the tail.  When free pages run short, least-recently-used
trie leaves are evicted (leaf-first keeps the index prefix-closed); a page
is returned to the free list exactly when its last holder — slot or trie —
lets go.

Host-memory tier (two-tier hierarchy)
-------------------------------------
With ``host_tier=True`` (and device callbacks attached via
:meth:`attach_tier`), eviction of a trie-only page becomes *demotion*:
the page's KV rows are fetched to a host-memory blob (numpy), the device
page is freed, and the trie node survives with ``page = HOST_PAGE`` — a
later prefix hit *promotes* it back onto a fresh device page instead of
recomputing the prefill.  Transfers are charged into a modelled energy
ledger (``bytes x transfer_j_per_byte``, read by the engine per chunk),
and a page is only demoted when the round trip is cheaper than
recomputing its rows (``_should_demote``); otherwise it is dropped as
before.  A demoted page lives in exactly one tier: its node holds no
device refcount, contributes nothing to ``n_evictable``, and costs one
device page of *headroom* when a prefix match wants it back — which is
exactly how ``can_admit_with_prefix`` accounts for it.

Invariants the decode path relies on:

  * pages 0..n_slots-1 are reserved per-slot *scratch* pages; a free slot's
    whole table row points at its scratch page, so parked slots can keep
    executing (write + attend on scratch garbage, output discarded) without
    any validity branch in the jitted loop;
  * a live slot's table rows beyond its allocation also point at scratch,
    so within-chunk overrun past a request's budget stays contained;
  * distinct slots never WRITE the same non-scratch page: shared pages are
    mapped strictly below each holder's write frontier (the suffix starts
    at or past the shared prefix), so the per-layer scatter in
    ``gqa_decode_paged`` / ``commit_spec_paged`` never collides across the
    batch;
  * ``refcount[p]`` equals the number of holders (slots mapping p + one if
    the trie indexes p); the free list is exactly the zero-refcount pages.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.models import transformer as tfm


@dataclasses.dataclass
class CopySpec:
    """Copy-on-write order emitted by ``admit_with_prefix`` for a partial
    page match: the engine must copy rows ``0..n_rows-1`` of ``src_page``
    into ``dst_page`` device-side, then call ``copy_done(src_page)`` to
    drop the read hold protecting the source from eviction-reuse."""
    src_page: int
    dst_page: int
    n_rows: int


# sentinel for a trie node whose KV lives in the host tier, not on device
HOST_PAGE = -2


def _np_dtype(name: str) -> np.dtype:
    """np.dtype by name, including the ml_dtypes extension types (numpy
    does not know "bfloat16" natively)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _blob_to_json(arr: np.ndarray) -> dict:
    a = np.asarray(arr)
    # floats round-trip exactly through their bit pattern, not repr
    bits = a.view(np.uint8)
    return {"data": bits.ravel().tolist(), "dtype": str(a.dtype),
            "shape": list(a.shape)}


def _blob_from_json(blob: dict) -> np.ndarray:
    dt = _np_dtype(blob["dtype"])
    a = np.asarray(blob["data"], np.uint8).view(dt)
    return a.reshape(blob["shape"])


class _TrieNode:
    """One full page of cached prefix: ``tokens`` (page_size ids), the
    physical page holding their KV, and children keyed on the next page's
    token bytes.  A demoted node has ``page == HOST_PAGE`` and carries the
    page's rows in ``host_data`` (unit/key -> numpy blob) instead."""
    __slots__ = ("key", "tokens", "page", "parent", "children", "last_used",
                 "host_data")

    def __init__(self, key, tokens, page, parent):
        self.key = key
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _TrieNode] = {}
        self.last_used = 0
        self.host_data: dict | None = None


class PagedKVCache:
    """Host-side page allocator for the paged decode cache."""

    def __init__(self, cfg, *, n_slots: int, page_size: int, max_len: int,
                 n_pages: int | None = None, dtype: str = "bfloat16",
                 host_tier: bool = False, host_pages: int | None = None,
                 transfer_j_per_byte: float = 1e-9,
                 recompute_j_per_token: float | None = None):
        blockers = tfm.paged_cache_blockers(cfg)
        if blockers:
            raise ValueError(f"{cfg.name}: paged KV cache blocked by "
                             f"{blockers[0]}")
        self.cfg = cfg
        # Families whose every cache group is slot-indexed (pure-SSM state
        # slots, all-windowed private rings, the hybrid shared buffer) have
        # no block-table-backed pool: the manager still owns slot parking,
        # but page allocation is a no-op and admission is purely a
        # slot-availability question.
        self.tables_active = self._has_table_group(cfg)
        self.n_slots = int(n_slots)
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.max_blocks = -(-self.max_len // self.page_size)
        if n_pages is None:
            # full provisioning: every slot can hold max_len, plus scratch
            n_pages = self.n_slots * self.max_blocks + self.n_slots
        self.n_pages = int(n_pages)
        self.dtype = dtype
        # scratch page s backs every unallocated block of slot s
        self.tables = np.arange(self.n_slots, dtype=np.int32)[:, None].repeat(
            self.max_blocks, axis=1)
        self.free: deque[int] = deque(range(self.n_slots, self.n_pages))
        self.allocated: dict[int, list[int]] = {}   # slot -> mapped pages
        self.refcount = np.zeros((self.n_pages,), np.int64)
        self._root = _TrieNode(None, None, -1, None)
        self._clock = 0
        self._copy_holds: dict[int, int] = {}       # page -> pending holds
        # Pages pulled from circulation by verify_invariants(repair=True):
        # corrupted metadata, no legitimate holder.  Never re-enter the
        # free list — capacity degrades gracefully instead of serving a
        # poisoned page.
        self.quarantined: set[int] = set()
        # host tier: demote-instead-of-evict for trie-only pages.  The
        # device transfer callbacks arrive via attach_tier (the manager is
        # layout-agnostic); until then demotion silently degrades to plain
        # eviction even with host_tier=True.
        self.host_tier = bool(host_tier)
        self.host_pages = None if host_pages is None else int(host_pages)
        self.transfer_j_per_byte = float(transfer_j_per_byte)
        self.recompute_j_per_token = recompute_j_per_token if \
            recompute_j_per_token is None else float(recompute_j_per_token)
        self._fetch_page = None       # page -> {unit/key: np blob}   (D2H)
        self._restore_page = None     # (page, blob) -> None          (H2D)
        self._page_bytes = 0          # device bytes of one page (all units)
        self.transfer_bytes_d2h = 0
        self.transfer_bytes_h2d = 0
        self.transfer_j = 0.0
        self.n_demotions = 0
        self.n_promotions = 0

    @staticmethod
    def _has_table_group(cfg) -> bool:
        """Does any cache group ride the shared page pools (vs per-slot
        state slots / private windowed rings / the hybrid shared buffer)?"""
        if cfg.first_dense_layers:
            return True
        if cfg.uses_ssm:            # ssm + hybrid: every sub is state-slot
            return False
        if cfg.use_mla:             # latent pool rides the main tables
            return True
        return any(cfg.window_for_layer(i) == 0
                   for i in range(tfm.unit_size(cfg)))

    # -- device side --------------------------------------------------------
    def make_cache(self):
        """Fresh zero-filled device cache pytree matching this manager."""
        return tfm.init_paged_cache(self.cfg, self.n_slots, self.n_pages,
                                    self.page_size, self.max_blocks,
                                    dtype=self.dtype)

    # -- host tier ----------------------------------------------------------
    def attach_tier(self, fetch_page, restore_page, page_bytes: int) -> None:
        """Wire the device transfer callbacks: ``fetch_page(page)`` returns
        the page's rows as a host blob (D2H), ``restore_page(page, blob)``
        writes a blob back into a device page (H2D), ``page_bytes`` is the
        device footprint of one page across every unit/layer (the quantity
        the transfer-energy model charges per direction)."""
        self._fetch_page = fetch_page
        self._restore_page = restore_page
        self._page_bytes = int(page_bytes)

    @property
    def _tier_ready(self) -> bool:
        return self.host_tier and self._fetch_page is not None

    def n_host_used(self) -> int:
        """Demoted pages currently parked in the host tier."""
        return sum(1 for node in self._all_nodes()
                   if node.host_data is not None)

    def _should_demote(self) -> bool:
        """Demote-vs-evict energy rule: page out only when the full round
        trip (D2H now + H2D on the future hit) costs less than recomputing
        the page's rows from tokens.  With no recompute price configured,
        transfer is assumed cheap (PCIe ~GB/s vs a prefill sweep) and cold
        pages always demote."""
        if self.recompute_j_per_token is None:
            return True
        round_trip = 2 * self._page_bytes * self.transfer_j_per_byte
        return round_trip <= self.page_size * self.recompute_j_per_token

    def _charge_transfer(self, n_bytes: int, *, h2d: bool) -> None:
        if h2d:
            self.transfer_bytes_h2d += n_bytes
        else:
            self.transfer_bytes_d2h += n_bytes
        self.transfer_j += n_bytes * self.transfer_j_per_byte

    def _demote(self, node: _TrieNode) -> None:
        """Page out a trie-only node: fetch its rows to host memory, free
        the device page, keep the trie entry alive at ``HOST_PAGE``."""
        node.host_data = self._fetch_page(node.page)
        self._charge_transfer(self._page_bytes, h2d=False)
        self._unhold(node.page)
        node.page = HOST_PAGE
        self.n_demotions += 1

    def _promote(self, node: _TrieNode, protect: set[int] | None = None) \
            -> bool:
        """Page a demoted node back onto a fresh device page (reclaiming
        one if the free list is dry — ``protect`` guards the other nodes
        of an in-flight prefix match from being cannibalised).  Returns
        False when no device page can be found; the node stays demoted."""
        if not self.free and not self._reclaim(1, protect=protect):
            return False
        page = self._take_free()            # refcount 1 = the trie's hold
        self._restore_page(page, node.host_data)
        self._charge_transfer(self._page_bytes, h2d=True)
        node.page = page
        node.host_data = None
        self.n_promotions += 1
        return True

    # -- refcount plumbing --------------------------------------------------
    def _hold(self, page: int) -> None:
        self.refcount[page] += 1

    def _unhold(self, page: int) -> None:
        self.refcount[page] -= 1
        if self.refcount[page] < 0:
            raise AssertionError(f"page {page}: refcount underflow")
        if self.refcount[page] == 0 and page not in self.quarantined:
            self.free.append(page)

    def _take_free(self) -> int:
        page = self.free.popleft()
        self._hold(page)
        return page

    # -- trie ---------------------------------------------------------------
    def _chunks(self, tokens: np.ndarray):
        """tokens split into full page_size chunks (bytes key + array)."""
        ps = self.page_size
        t = np.ascontiguousarray(np.asarray(tokens))
        for j in range(len(t) // ps):
            chunk = t[j * ps:(j + 1) * ps]
            yield chunk.tobytes(), chunk

    def _match(self, tokens: np.ndarray):
        """Longest cached prefix of ``tokens``, capped at ``len - 1`` (at
        least one token is always left to prefill so its logits exist).
        Returns (full_nodes, partial) where partial is (node, n_rows) for a
        match ending inside a page, or None."""
        t = np.asarray(tokens)
        max_share = len(t) - 1
        node, full = self._root, []
        for key, chunk in self._chunks(t):
            if (len(full) + 1) * self.page_size > max_share:
                break
            child = node.children.get(key)
            if child is None:
                break
            full.append(child)
            node = child
        off = len(full) * self.page_size
        rem = min(self.page_size, max_share - off)
        partial = None
        if rem > 0:
            want = np.asarray(t[off:off + rem]).reshape(rem, -1)
            best, best_n = None, 0
            for child in node.children.values():
                have = np.asarray(child.tokens).reshape(self.page_size, -1)
                eq = np.all(have[:rem] == want, axis=1)
                n = int(eq.argmin()) if not eq.all() else rem
                if n > best_n:
                    best, best_n = child, n
            if best is not None:
                partial = (best, best_n)
        return full, partial

    def _leaves(self):
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            if node is not self._root and not node.children:
                out.append(node)
            stack.extend(node.children.values())
        return out

    def _evict_one(self, protect: set[int] | None = None) -> bool:
        """Surrender one trie-held device page.

        When the host tier is live and the energy rule favours transfer,
        the LRU *trie-only* node anywhere in the trie — leaf or interior —
        *demotes*: its rows page out, the device page frees, the node
        survives at ``HOST_PAGE``.  Demotion keeps the trie structurally
        intact, so leaf-first does not apply; residency of a prefix may be
        a patchwork across tiers and promotion restores matched nodes one
        by one.  Otherwise the classic path drops the LRU leaf (leaf-first
        keeps the *index* prefix-closed), freeing its page iff the trie was
        the last holder; and when only demoted leaves remain, the LRU one
        is dropped outright if that can eventually expose a resident page
        (its host blob dies — the tier is a cache, not an archive).
        ``protect`` exempts nodes of an in-flight prefix match.  Returns
        False when nothing can go."""
        protect = protect or set()
        if (self._tier_ready and self._should_demote()
                and (self.host_pages is None
                     or self.n_host_used() < self.host_pages)):
            cands = [n for n in self._all_nodes()
                     if id(n) not in protect and n.page >= 0
                     and self.refcount[n.page] == 1]
            if cands:
                self._demote(min(cands, key=lambda n: n.last_used))
                return True
        leaves = [n for n in self._leaves() if id(n) not in protect]
        resident = [n for n in leaves if n.page >= 0]
        if resident:
            victim = min(resident, key=lambda n:
                         (self.refcount[n.page] > 1, n.last_used))
            del victim.parent.children[victim.key]
            self._unhold(victim.page)
            return True
        # no resident leaf: dropping a demoted leaf frees no device page
        # directly, but may expose a resident interior node as a new leaf —
        # worth it only if such a node exists at all
        demoted = [n for n in leaves if n.host_data is not None]
        if demoted and self.n_evictable() > 0:
            victim = min(demoted, key=lambda n: n.last_used)
            del victim.parent.children[victim.key]
            victim.host_data = None
            return True
        return False

    def _reclaim(self, n_pages: int,
                 protect: set[int] | None = None) -> bool:
        """Evict/demote trie entries until at least ``n_pages`` are free."""
        while len(self.free) < n_pages:
            if not self._evict_one(protect=protect):
                return False
        return True

    def n_evictable(self) -> int:
        """Pages the trie could surrender (trie is their only holder).
        Demoted nodes hold no device page and count for nothing here."""
        count, stack = 0, [self._root]
        while stack:
            node = stack.pop()
            if node is not self._root and node.page >= 0 \
                    and self.refcount[node.page] == 1:
                count += 1
            stack.extend(node.children.values())
        return count

    # -- allocation ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 1) // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        if not self.tables_active:      # no pools: slots are the only gate
            return True
        return self.pages_for(n_tokens) <= len(self.free) + self.n_evictable()

    def can_admit_with_prefix(self, tokens: np.ndarray,
                              n_tokens: int) -> bool:
        """Like ``can_admit`` but crediting pages the prefix cache already
        holds for ``tokens`` — sharing raises admissible concurrency.
        Matched pages are about to be *held*, not freed, so they must not
        double-count as evictable headroom.  Two-tier accounting: a
        matched *demoted* page saves the prefill but still needs a fresh
        device page to promote onto (+1 to need, nothing reserved); a
        matched resident page whose only holder is the trie would have
        counted as evictable headroom, so it is subtracted back out."""
        if not self.tables_active:
            return True
        full, partial = self._match(tokens)
        n_blocks = self.pages_for(n_tokens)
        full = full[:n_blocks]
        need = n_blocks - len(full)
        need += sum(1 for node in full if node.page < 0)
        reserved = sum(1 for node in full
                       if node.page >= 0 and self.refcount[node.page] == 1)
        if partial is not None and len(full) < n_blocks:
            if partial[0].page < 0:
                need += 1
            elif self.refcount[partial[0].page] == 1:
                reserved += 1
        return need <= len(self.free) + self.n_evictable() - reserved

    def admit(self, slot: int, n_tokens: int) -> list[int]:
        """Allocate private pages covering ``n_tokens`` context positions
        for ``slot`` and point its table's leading blocks at them (no
        prefix sharing — the legacy entry point)."""
        pages = self._admit_pages(slot, self.pages_for(n_tokens), [])
        return pages

    def admit_with_prefix(self, slot: int, tokens: np.ndarray,
                          n_tokens: int) -> tuple[int, CopySpec | None]:
        """Map the longest cached prefix of ``tokens`` onto shared
        read-only pages and allocate private pages for the rest (covering
        ``n_tokens`` context positions total).

        Returns ``(matched_len, copy)``: the engine prefills only
        ``tokens[matched_len:]``.  ``copy`` (when the match ends inside a
        page) orders a device-side copy of the matched rows into the
        slot's first private page — copy-on-write, since the suffix
        prefill is about to write right behind them."""
        full, partial = self._match(tokens)
        n_blocks = self.pages_for(n_tokens)
        if len(full) > n_blocks:       # prompt cached deeper than the alloc
            full = full[:n_blocks]
            partial = None
        if partial is not None and len(full) >= n_blocks:
            partial = None
        # promote demoted matches back onto device pages, in prefix order;
        # the first failed promotion truncates the match there (the rest of
        # the prefix is unreachable without it).  The whole match is
        # protected from reclaim-eviction while promotions run.
        protect = {id(n) for n in full}
        if partial is not None:
            protect.add(id(partial[0]))
        usable = []
        for node in full:
            if node.page < 0 and not self._promote(node, protect=protect):
                partial = None
                break
            usable.append(node)
        else:
            if partial is not None and partial[0].page < 0 \
                    and not self._promote(partial[0], protect=protect):
                partial = None
        full = usable
        shared = []
        for node in full:
            self._hold(node.page)
            node.last_used = self._clock
            self._clock += 1
            shared.append(node.page)
        copy_src = None
        if partial is not None:
            node, rows = partial
            node.last_used = self._clock
            self._clock += 1
            # protect the source page from evict-and-reuse (the reclaim
            # inside _admit_pages included) until the engine has executed
            # the copy
            self._hold(node.page)
            self._copy_holds[node.page] = \
                self._copy_holds.get(node.page, 0) + 1
            copy_src = (node.page, rows)
        try:
            self._admit_pages(slot, n_blocks, shared)
        except ValueError:
            for p in shared:
                self._unhold(p)
            if copy_src is not None:
                self.copy_done(copy_src[0])
            raise
        matched = len(full) * self.page_size
        copy = None
        if copy_src is not None:
            copy = CopySpec(src_page=copy_src[0],
                            dst_page=int(self.tables[slot, len(full)]),
                            n_rows=copy_src[1])
            matched += copy_src[1]
        return matched, copy

    def _admit_pages(self, slot: int, n_blocks: int,
                     shared: list[int]) -> list[int]:
        if slot in self.allocated:
            raise ValueError(f"slot {slot} already holds an allocation")
        if not self.tables_active:
            assert not shared
            self.allocated[slot] = []
            self.tables[slot, :] = slot
            return []
        if n_blocks > self.max_blocks:
            raise ValueError(f"request needs {n_blocks} blocks > table "
                             f"width {self.max_blocks} "
                             f"(max_len {self.max_len})")
        need = n_blocks - len(shared)
        if not self._reclaim(need):
            raise ValueError(f"slot {slot}: {need} pages needed, "
                             f"{len(self.free)} free")
        pages = list(shared) + [self._take_free() for _ in range(need)]
        self.tables[slot, :] = slot                 # park the tail on scratch
        self.tables[slot, :n_blocks] = pages
        self.allocated[slot] = pages
        return pages

    def copy_done(self, src_page: int) -> None:
        """Release the read hold taken for a pending ``CopySpec``."""
        holds = self._copy_holds.get(src_page, 0)
        if holds <= 0:
            raise ValueError(f"page {src_page}: no pending copy hold")
        if holds == 1:
            del self._copy_holds[src_page]
        else:
            self._copy_holds[src_page] = holds - 1
        self._unhold(src_page)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s allocation to cover ``n_tokens`` context
        positions, evicting cached prefixes if needed.  Returns False when
        the pool cannot provide (the scheduler preempts someone)."""
        if slot not in self.allocated:
            raise ValueError(f"slot {slot} is not allocated")
        if not self.tables_active:
            return True
        need = self.pages_for(n_tokens)
        if need > self.max_blocks:
            raise ValueError(f"slot {slot}: {need} blocks > table width "
                             f"{self.max_blocks} (max_len {self.max_len})")
        cur = len(self.allocated[slot])
        if need <= cur:
            return True
        if not self._reclaim(need - cur):
            return False
        for j in range(cur, need):
            page = self._take_free()
            self.tables[slot, j] = page
            self.allocated[slot].append(page)
        return True

    def register_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Index ``slot``'s now-written pages in the prefix trie: every
        full page of ``tokens`` (KV must already be committed for all of
        them).  Pages already indexed for the same token prefix are left
        alone — the slot's duplicate stays private and dies with it."""
        if not self.tables_active:
            return
        n_blocks = len(self.allocated.get(slot, ()))
        node = self._root
        for j, (key, chunk) in enumerate(self._chunks(tokens)):
            if j >= n_blocks:
                break
            child = node.children.get(key)
            if child is None:
                page = int(self.tables[slot, j])
                child = _TrieNode(key, chunk.copy(), page, node)
                node.children[key] = child
                self._hold(page)
            child.last_used = self._clock
            self._clock += 1
            node = child

    def release(self, slot: int) -> None:
        """Drop ``slot``'s holds and park it.  Pages the trie still
        indexes survive as cached prefixes; the rest return to the free
        list."""
        for page in self.allocated.pop(slot, []):
            self._unhold(page)
        self.tables[slot, :] = slot

    # -- injection helper ---------------------------------------------------
    def inject_rows(self, slot: int, bucket_len: int, n_valid: int) -> np.ndarray:
        """Flat pool-row destinations for copying a prefill cache (padded to
        ``bucket_len``) into ``slot``'s pages.  Rows past ``n_valid`` (the
        real prompt length) map out of bounds and are dropped by the
        ``mode="drop"`` scatter."""
        rows = np.empty((bucket_len,), np.int32)
        for i in range(bucket_len):
            if i < n_valid:
                page = self.tables[slot, i // self.page_size]
                rows[i] = page * self.page_size + i % self.page_size
            else:
                rows[i] = self.n_pages * self.page_size    # dropped
        return rows

    # -- audit ----------------------------------------------------------------
    def _all_nodes(self) -> list[_TrieNode]:
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            if node is not self._root:
                out.append(node)
            stack.extend(node.children.values())
        return out

    def _expected_holders(self) -> np.ndarray:
        """Ground-truth refcounts recomputed from the holder structures:
        slots mapping the page + trie nodes indexing it + pending copy
        holds.  ``refcount`` must equal this exactly."""
        exp = np.zeros((self.n_pages,), np.int64)
        for pages in self.allocated.values():
            for p in pages:
                exp[p] += 1
        for node in self._all_nodes():
            if 0 <= node.page < self.n_pages:
                exp[node.page] += 1
        for p, holds in self._copy_holds.items():
            exp[p] += holds
        return exp

    def verify_invariants(self, *, repair: bool = False) -> list[str]:
        """Audit the host metadata against the invariants the decode path
        relies on.  Returns the violations found (empty = clean).

        With ``repair=True`` the pool is additionally put back into a safe
        state: corrupted trie subtrees are dropped, refcounts of pages
        with legitimate holders are recomputed, and implicated pages with
        *no* holder are quarantined (withheld from the free list) rather
        than recirculated — serving degrades capacity instead of crashing
        or handing out a poisoned page.  Runs on engine restore and on
        demand (chaos drills).
        """
        violations: list[str] = []
        free_set = set(self.free)
        # 1. trie pages must be real, non-scratch, and not on the free
        # list; a node lives in exactly one tier — demoted (HOST_PAGE +
        # host blob) or resident (valid device page, no blob)
        bad_nodes = []
        for node in self._all_nodes():
            if node.page == HOST_PAGE and node.host_data is not None:
                continue                             # healthy demoted node
            if node.page == HOST_PAGE:
                violations.append("tier: demoted node lost its host blob")
                bad_nodes.append(node)
            elif not (self.n_slots <= node.page < self.n_pages):
                violations.append(f"trie: node holds invalid page "
                                  f"{node.page}")
                bad_nodes.append(node)
            elif node.host_data is not None:
                violations.append(f"tier: page {node.page} present in both "
                                  "tiers (resident with a host blob)")
                bad_nodes.append(node)
            elif node.page in free_set:
                violations.append(f"trie: node points at freed page "
                                  f"{node.page} (stale)")
                bad_nodes.append(node)
        implicated = {n.page for n in bad_nodes if n.page >= 0}
        if repair:
            for node in bad_nodes:
                # drop the whole subtree: children cached *behind* a bad
                # page are unreachable by prefix anyway
                if node.key in node.parent.children \
                        and node.parent.children[node.key] is node:
                    del node.parent.children[node.key]
        # 2. free-list duplicates
        seen: set[int] = set()
        for p in self.free:
            if p in seen:
                violations.append(f"free: page {p} listed more than once")
                implicated.add(p)
            seen.add(p)
        # 3. refcount == holders; free list == zero-refcount pages
        exp = self._expected_holders()
        for p in range(self.n_slots, self.n_pages):
            if p in self.quarantined:
                continue
            if self.refcount[p] != exp[p]:
                violations.append(f"refcount: page {p} is "
                                  f"{int(self.refcount[p])}, holders say "
                                  f"{int(exp[p])}")
                implicated.add(p)
            if exp[p] == 0 and p not in seen and p not in implicated:
                violations.append(f"free: page {p} has no holder but is "
                                  "not on the free list")
                implicated.add(p)
        for p in range(self.n_slots):               # scratch never circulates
            if self.refcount[p] != exp[p] or p in seen:
                violations.append(f"scratch: page {p} leaked into "
                                  "circulation")
                implicated.add(p)
        # 4. host-tier budget
        if self.host_pages is not None:
            used = self.n_host_used()
            if used > self.host_pages:
                violations.append(f"tier: {used} demoted pages exceed the "
                                  f"host pool budget {self.host_pages}")
        if repair and violations:
            for p in implicated:
                if exp[p] > 0:
                    self.refcount[p] = exp[p]       # holders are the truth
                elif p >= self.n_slots:
                    self.quarantined.add(p)         # no holder: withhold
                    self.refcount[p] = 0
            # rebuild the free list: keep surviving entries in order (free
            # order determines future page assignment), append recovered
            # strays, drop quarantined/held/duplicate entries
            rebuilt, emitted = [], set()
            for p in self.free:
                if p >= self.n_slots and exp[p] == 0 and p not in emitted \
                        and p not in self.quarantined:
                    rebuilt.append(p)
                    emitted.add(p)
            for p in range(self.n_slots, self.n_pages):
                if exp[p] == 0 and p not in emitted \
                        and p not in self.quarantined:
                    rebuilt.append(p)
                    emitted.add(p)
            self.free = deque(rebuilt)
        return violations

    # -- snapshot / restore ---------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serialisable host metadata: block tables, free list,
        allocations, refcounts, and the prefix trie (BFS order, parent
        links).  The device pools are snapshotted separately — together
        they rebuild an identical manager via :meth:`load_state`."""
        nodes: list[dict] = []
        queue = deque([(self._root, -1)])
        while queue:
            node, parent = queue.popleft()
            if node is not self._root:
                rec = {
                    "parent": parent,
                    "page": int(node.page),
                    "tokens": np.asarray(node.tokens).ravel().tolist(),
                    "dtype": str(np.asarray(node.tokens).dtype),
                    "last_used": int(node.last_used),
                }
                if node.host_data is not None:
                    rec["host"] = {name: _blob_to_json(arr)
                                   for name, arr in node.host_data.items()}
                nodes.append(rec)
                parent_idx = len(nodes) - 1
            else:
                parent_idx = -1
            for child in node.children.values():    # insertion order kept
                queue.append((child, parent_idx))
        return {
            "n_slots": self.n_slots, "page_size": self.page_size,
            "max_len": self.max_len, "n_pages": self.n_pages,
            "tables": self.tables.tolist(),
            "free": list(self.free),
            "allocated": {str(s): list(p) for s, p in self.allocated.items()},
            "refcount": self.refcount.tolist(),
            "copy_holds": {str(p): h for p, h in self._copy_holds.items()},
            "quarantined": sorted(self.quarantined),
            "clock": self._clock,
            "trie": nodes,
            "transfer": {
                "bytes_d2h": self.transfer_bytes_d2h,
                "bytes_h2d": self.transfer_bytes_h2d,
                "transfer_j": self.transfer_j,
                "n_demotions": self.n_demotions,
                "n_promotions": self.n_promotions,
            },
        }

    def load_state(self, state: dict) -> None:
        """Rebuild the manager in place from :meth:`state_dict` output."""
        for field in ("n_slots", "page_size", "max_len", "n_pages"):
            if int(state[field]) != getattr(self, field):
                raise ValueError(f"snapshot {field}={state[field]} does not "
                                 f"match pool ({getattr(self, field)})")
        self.tables = np.asarray(state["tables"], np.int32)
        self.free = deque(int(p) for p in state["free"])
        self.allocated = {int(s): [int(p) for p in pages]
                          for s, pages in state["allocated"].items()}
        self.refcount = np.asarray(state["refcount"], np.int64)
        self._copy_holds = {int(p): int(h)
                            for p, h in state["copy_holds"].items()}
        self.quarantined = {int(p) for p in state.get("quarantined", ())}
        self._clock = int(state["clock"])
        self._root = _TrieNode(None, None, -1, None)
        rebuilt: list[_TrieNode] = []
        for rec in state["trie"]:
            tokens = np.asarray(rec["tokens"], dtype=rec["dtype"])
            key = np.ascontiguousarray(tokens).tobytes()
            parent = self._root if rec["parent"] < 0 \
                else rebuilt[rec["parent"]]
            node = _TrieNode(key, tokens, int(rec["page"]), parent)
            node.last_used = int(rec["last_used"])
            if "host" in rec:
                node.host_data = {name: _blob_from_json(blob)
                                  for name, blob in rec["host"].items()}
            parent.children[key] = node
            rebuilt.append(node)
        xfer = state.get("transfer", {})
        self.transfer_bytes_d2h = int(xfer.get("bytes_d2h", 0))
        self.transfer_bytes_h2d = int(xfer.get("bytes_h2d", 0))
        self.transfer_j = float(xfer.get("transfer_j", 0.0))
        self.n_demotions = int(xfer.get("n_demotions", 0))
        self.n_promotions = int(xfer.get("n_promotions", 0))

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def occupancy(self) -> float:
        """Fraction of non-scratch pages currently allocated."""
        usable = self.n_pages - self.n_slots
        return 1.0 - len(self.free) / max(usable, 1)
