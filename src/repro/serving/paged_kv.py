"""Paged KV-cache manager: ref-counted pages + prefix sharing + free list.

Replaces per-request ring buffers with a pool of fixed-size pages shared by
every decode slot (vLLM's PagedAttention layout, collapsed to the needs of
this engine).  The device side — per-unit pools of shape ``(n_units,
n_pages, page_size, Hkv, hd)`` plus per-slot ``block_tables``/``pos`` —
comes from :func:`repro.models.transformer.init_paged_cache`; this class
owns the *host* side: which physical page backs which logical block of
which slot, which pages are free, and — new in this layer — which pages
hold a **cached prompt prefix** that future requests can map read-only
instead of recomputing.

Prefix sharing
--------------
Pages are immutable once full, and a page's KV rows depend only on the
token ids of the whole prefix up to and including that page (RoPE is
applied at absolute positions, and the layout is linear: block ``j`` holds
positions ``[j*ps, (j+1)*ps)``).  So a trie keyed on page-sized token
chunks indexes every cached prefix: ``admit_with_prefix`` walks it and maps
the longest cached prefix onto shared read-only pages (refcount + 1 each),
allocating private pages only for the uncached suffix.  When the match
ends inside a page (the common system prompt is rarely page-aligned), the
shared page cannot be mapped directly — the suffix prefill would write
into it — so the manager emits a **copy-on-write** spec: the engine copies
the matched rows into the slot's private page device-side and only then
writes the suffix behind them.

The trie itself holds one reference per indexed page, so a released
request's prefix pages *survive* until evicted — this is what makes
preemption cheap: a preempted request re-queued with its generated tokens
folded into the prompt finds nearly all of its pages still cached and
prefills only the tail.  When free pages run short, least-recently-used
trie leaves are evicted (leaf-first keeps the index prefix-closed); a page
is returned to the free list exactly when its last holder — slot or trie —
lets go.

Invariants the decode path relies on:

  * pages 0..n_slots-1 are reserved per-slot *scratch* pages; a free slot's
    whole table row points at its scratch page, so parked slots can keep
    executing (write + attend on scratch garbage, output discarded) without
    any validity branch in the jitted loop;
  * a live slot's table rows beyond its allocation also point at scratch,
    so within-chunk overrun past a request's budget stays contained;
  * distinct slots never WRITE the same non-scratch page: shared pages are
    mapped strictly below each holder's write frontier (the suffix starts
    at or past the shared prefix), so the per-layer scatter in
    ``gqa_decode_paged`` / ``commit_spec_paged`` never collides across the
    batch;
  * ``refcount[p]`` equals the number of holders (slots mapping p + one if
    the trie indexes p); the free list is exactly the zero-refcount pages.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.models import transformer as tfm


@dataclasses.dataclass
class CopySpec:
    """Copy-on-write order emitted by ``admit_with_prefix`` for a partial
    page match: the engine must copy rows ``0..n_rows-1`` of ``src_page``
    into ``dst_page`` device-side, then call ``copy_done(src_page)`` to
    drop the read hold protecting the source from eviction-reuse."""
    src_page: int
    dst_page: int
    n_rows: int


class _TrieNode:
    """One full page of cached prefix: ``tokens`` (page_size ids), the
    physical page holding their KV, and children keyed on the next page's
    token bytes."""
    __slots__ = ("key", "tokens", "page", "parent", "children", "last_used")

    def __init__(self, key, tokens, page, parent):
        self.key = key
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _TrieNode] = {}
        self.last_used = 0


class PagedKVCache:
    """Host-side page allocator for the paged decode cache."""

    def __init__(self, cfg, *, n_slots: int, page_size: int, max_len: int,
                 n_pages: int | None = None, dtype: str = "bfloat16"):
        if not tfm.supports_paged_cache(cfg):
            raise ValueError(f"{cfg.name}: paged KV cache supports dense "
                             "GQA families only (no ssm/mla/window/hybrid)")
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.max_blocks = -(-self.max_len // self.page_size)
        if n_pages is None:
            # full provisioning: every slot can hold max_len, plus scratch
            n_pages = self.n_slots * self.max_blocks + self.n_slots
        self.n_pages = int(n_pages)
        self.dtype = dtype
        # scratch page s backs every unallocated block of slot s
        self.tables = np.arange(self.n_slots, dtype=np.int32)[:, None].repeat(
            self.max_blocks, axis=1)
        self.free: deque[int] = deque(range(self.n_slots, self.n_pages))
        self.allocated: dict[int, list[int]] = {}   # slot -> mapped pages
        self.refcount = np.zeros((self.n_pages,), np.int64)
        self._root = _TrieNode(None, None, -1, None)
        self._clock = 0
        self._copy_holds: dict[int, int] = {}       # page -> pending holds
        # Pages pulled from circulation by verify_invariants(repair=True):
        # corrupted metadata, no legitimate holder.  Never re-enter the
        # free list — capacity degrades gracefully instead of serving a
        # poisoned page.
        self.quarantined: set[int] = set()

    # -- device side --------------------------------------------------------
    def make_cache(self):
        """Fresh zero-filled device cache pytree matching this manager."""
        return tfm.init_paged_cache(self.cfg, self.n_slots, self.n_pages,
                                    self.page_size, self.max_blocks,
                                    dtype=self.dtype)

    # -- refcount plumbing --------------------------------------------------
    def _hold(self, page: int) -> None:
        self.refcount[page] += 1

    def _unhold(self, page: int) -> None:
        self.refcount[page] -= 1
        if self.refcount[page] < 0:
            raise AssertionError(f"page {page}: refcount underflow")
        if self.refcount[page] == 0 and page not in self.quarantined:
            self.free.append(page)

    def _take_free(self) -> int:
        page = self.free.popleft()
        self._hold(page)
        return page

    # -- trie ---------------------------------------------------------------
    def _chunks(self, tokens: np.ndarray):
        """tokens split into full page_size chunks (bytes key + array)."""
        ps = self.page_size
        t = np.ascontiguousarray(np.asarray(tokens))
        for j in range(len(t) // ps):
            chunk = t[j * ps:(j + 1) * ps]
            yield chunk.tobytes(), chunk

    def _match(self, tokens: np.ndarray):
        """Longest cached prefix of ``tokens``, capped at ``len - 1`` (at
        least one token is always left to prefill so its logits exist).
        Returns (full_nodes, partial) where partial is (node, n_rows) for a
        match ending inside a page, or None."""
        t = np.asarray(tokens)
        max_share = len(t) - 1
        node, full = self._root, []
        for key, chunk in self._chunks(t):
            if (len(full) + 1) * self.page_size > max_share:
                break
            child = node.children.get(key)
            if child is None:
                break
            full.append(child)
            node = child
        off = len(full) * self.page_size
        rem = min(self.page_size, max_share - off)
        partial = None
        if rem > 0:
            want = np.asarray(t[off:off + rem]).reshape(rem, -1)
            best, best_n = None, 0
            for child in node.children.values():
                have = np.asarray(child.tokens).reshape(self.page_size, -1)
                eq = np.all(have[:rem] == want, axis=1)
                n = int(eq.argmin()) if not eq.all() else rem
                if n > best_n:
                    best, best_n = child, n
            if best is not None:
                partial = (best, best_n)
        return full, partial

    def _leaves(self):
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            if node is not self._root and not node.children:
                out.append(node)
            stack.extend(node.children.values())
        return out

    def _evict_one(self) -> bool:
        """Drop a trie leaf (leaf-first keeps the index prefix-closed):
        prefer leaves whose page the trie alone holds (evicting those
        actually frees a page), least-recently-used among them.  Frees the
        page iff the trie was the last holder."""
        leaves = self._leaves()
        if not leaves:
            return False
        victim = min(leaves,
                     key=lambda n: (self.refcount[n.page] > 1, n.last_used))
        del victim.parent.children[victim.key]
        self._unhold(victim.page)
        return True

    def _reclaim(self, n_pages: int) -> bool:
        """Evict trie entries until at least ``n_pages`` are free."""
        while len(self.free) < n_pages:
            if not self._evict_one():
                return False
        return True

    def n_evictable(self) -> int:
        """Pages the trie could surrender (trie is their only holder)."""
        count, stack = 0, [self._root]
        while stack:
            node = stack.pop()
            if node is not self._root and self.refcount[node.page] == 1:
                count += 1
            stack.extend(node.children.values())
        return count

    # -- allocation ---------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 1) // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self.free) + self.n_evictable()

    def can_admit_with_prefix(self, tokens: np.ndarray,
                              n_tokens: int) -> bool:
        """Like ``can_admit`` but crediting pages the prefix cache already
        holds for ``tokens`` — sharing raises admissible concurrency.
        Matched pages are about to be *held*, not freed, so they must not
        double-count as evictable headroom."""
        full, partial = self._match(tokens)
        n_blocks = self.pages_for(n_tokens)
        full = full[:n_blocks]
        need = n_blocks - len(full)
        reserved = sum(1 for node in full if self.refcount[node.page] == 1)
        if partial is not None and len(full) < n_blocks \
                and self.refcount[partial[0].page] == 1:
            reserved += 1
        return need <= len(self.free) + self.n_evictable() - reserved

    def admit(self, slot: int, n_tokens: int) -> list[int]:
        """Allocate private pages covering ``n_tokens`` context positions
        for ``slot`` and point its table's leading blocks at them (no
        prefix sharing — the legacy entry point)."""
        pages = self._admit_pages(slot, self.pages_for(n_tokens), [])
        return pages

    def admit_with_prefix(self, slot: int, tokens: np.ndarray,
                          n_tokens: int) -> tuple[int, CopySpec | None]:
        """Map the longest cached prefix of ``tokens`` onto shared
        read-only pages and allocate private pages for the rest (covering
        ``n_tokens`` context positions total).

        Returns ``(matched_len, copy)``: the engine prefills only
        ``tokens[matched_len:]``.  ``copy`` (when the match ends inside a
        page) orders a device-side copy of the matched rows into the
        slot's first private page — copy-on-write, since the suffix
        prefill is about to write right behind them."""
        full, partial = self._match(tokens)
        n_blocks = self.pages_for(n_tokens)
        if len(full) > n_blocks:       # prompt cached deeper than the alloc
            full = full[:n_blocks]
            partial = None
        if partial is not None and len(full) >= n_blocks:
            partial = None
        shared = []
        for node in full:
            self._hold(node.page)
            node.last_used = self._clock
            self._clock += 1
            shared.append(node.page)
        copy_src = None
        if partial is not None:
            node, rows = partial
            node.last_used = self._clock
            self._clock += 1
            # protect the source page from evict-and-reuse (the reclaim
            # inside _admit_pages included) until the engine has executed
            # the copy
            self._hold(node.page)
            self._copy_holds[node.page] = \
                self._copy_holds.get(node.page, 0) + 1
            copy_src = (node.page, rows)
        try:
            self._admit_pages(slot, n_blocks, shared)
        except ValueError:
            for p in shared:
                self._unhold(p)
            if copy_src is not None:
                self.copy_done(copy_src[0])
            raise
        matched = len(full) * self.page_size
        copy = None
        if copy_src is not None:
            copy = CopySpec(src_page=copy_src[0],
                            dst_page=int(self.tables[slot, len(full)]),
                            n_rows=copy_src[1])
            matched += copy_src[1]
        return matched, copy

    def _admit_pages(self, slot: int, n_blocks: int,
                     shared: list[int]) -> list[int]:
        if slot in self.allocated:
            raise ValueError(f"slot {slot} already holds an allocation")
        if n_blocks > self.max_blocks:
            raise ValueError(f"request needs {n_blocks} blocks > table "
                             f"width {self.max_blocks} "
                             f"(max_len {self.max_len})")
        need = n_blocks - len(shared)
        if not self._reclaim(need):
            raise ValueError(f"slot {slot}: {need} pages needed, "
                             f"{len(self.free)} free")
        pages = list(shared) + [self._take_free() for _ in range(need)]
        self.tables[slot, :] = slot                 # park the tail on scratch
        self.tables[slot, :n_blocks] = pages
        self.allocated[slot] = pages
        return pages

    def copy_done(self, src_page: int) -> None:
        """Release the read hold taken for a pending ``CopySpec``."""
        holds = self._copy_holds.get(src_page, 0)
        if holds <= 0:
            raise ValueError(f"page {src_page}: no pending copy hold")
        if holds == 1:
            del self._copy_holds[src_page]
        else:
            self._copy_holds[src_page] = holds - 1
        self._unhold(src_page)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s allocation to cover ``n_tokens`` context
        positions, evicting cached prefixes if needed.  Returns False when
        the pool cannot provide (the scheduler preempts someone)."""
        if slot not in self.allocated:
            raise ValueError(f"slot {slot} is not allocated")
        need = self.pages_for(n_tokens)
        if need > self.max_blocks:
            raise ValueError(f"slot {slot}: {need} blocks > table width "
                             f"{self.max_blocks} (max_len {self.max_len})")
        cur = len(self.allocated[slot])
        if need <= cur:
            return True
        if not self._reclaim(need - cur):
            return False
        for j in range(cur, need):
            page = self._take_free()
            self.tables[slot, j] = page
            self.allocated[slot].append(page)
        return True

    def register_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Index ``slot``'s now-written pages in the prefix trie: every
        full page of ``tokens`` (KV must already be committed for all of
        them).  Pages already indexed for the same token prefix are left
        alone — the slot's duplicate stays private and dies with it."""
        n_blocks = len(self.allocated.get(slot, ()))
        node = self._root
        for j, (key, chunk) in enumerate(self._chunks(tokens)):
            if j >= n_blocks:
                break
            child = node.children.get(key)
            if child is None:
                page = int(self.tables[slot, j])
                child = _TrieNode(key, chunk.copy(), page, node)
                node.children[key] = child
                self._hold(page)
            child.last_used = self._clock
            self._clock += 1
            node = child

    def release(self, slot: int) -> None:
        """Drop ``slot``'s holds and park it.  Pages the trie still
        indexes survive as cached prefixes; the rest return to the free
        list."""
        for page in self.allocated.pop(slot, []):
            self._unhold(page)
        self.tables[slot, :] = slot

    # -- injection helper ---------------------------------------------------
    def inject_rows(self, slot: int, bucket_len: int, n_valid: int) -> np.ndarray:
        """Flat pool-row destinations for copying a prefill cache (padded to
        ``bucket_len``) into ``slot``'s pages.  Rows past ``n_valid`` (the
        real prompt length) map out of bounds and are dropped by the
        ``mode="drop"`` scatter."""
        rows = np.empty((bucket_len,), np.int32)
        for i in range(bucket_len):
            if i < n_valid:
                page = self.tables[slot, i // self.page_size]
                rows[i] = page * self.page_size + i % self.page_size
            else:
                rows[i] = self.n_pages * self.page_size    # dropped
        return rows

    # -- audit ----------------------------------------------------------------
    def _all_nodes(self) -> list[_TrieNode]:
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            if node is not self._root:
                out.append(node)
            stack.extend(node.children.values())
        return out

    def _expected_holders(self) -> np.ndarray:
        """Ground-truth refcounts recomputed from the holder structures:
        slots mapping the page + trie nodes indexing it + pending copy
        holds.  ``refcount`` must equal this exactly."""
        exp = np.zeros((self.n_pages,), np.int64)
        for pages in self.allocated.values():
            for p in pages:
                exp[p] += 1
        for node in self._all_nodes():
            if 0 <= node.page < self.n_pages:
                exp[node.page] += 1
        for p, holds in self._copy_holds.items():
            exp[p] += holds
        return exp

    def verify_invariants(self, *, repair: bool = False) -> list[str]:
        """Audit the host metadata against the invariants the decode path
        relies on.  Returns the violations found (empty = clean).

        With ``repair=True`` the pool is additionally put back into a safe
        state: corrupted trie subtrees are dropped, refcounts of pages
        with legitimate holders are recomputed, and implicated pages with
        *no* holder are quarantined (withheld from the free list) rather
        than recirculated — serving degrades capacity instead of crashing
        or handing out a poisoned page.  Runs on engine restore and on
        demand (chaos drills).
        """
        violations: list[str] = []
        free_set = set(self.free)
        # 1. trie pages must be real, non-scratch, and not on the free list
        bad_nodes = []
        for node in self._all_nodes():
            if not (self.n_slots <= node.page < self.n_pages):
                violations.append(f"trie: node holds invalid page "
                                  f"{node.page}")
                bad_nodes.append(node)
            elif node.page in free_set:
                violations.append(f"trie: node points at freed page "
                                  f"{node.page} (stale)")
                bad_nodes.append(node)
        implicated = {n.page for n in bad_nodes}
        if repair:
            for node in bad_nodes:
                # drop the whole subtree: children cached *behind* a bad
                # page are unreachable by prefix anyway
                if node.key in node.parent.children \
                        and node.parent.children[node.key] is node:
                    del node.parent.children[node.key]
        # 2. free-list duplicates
        seen: set[int] = set()
        for p in self.free:
            if p in seen:
                violations.append(f"free: page {p} listed more than once")
                implicated.add(p)
            seen.add(p)
        # 3. refcount == holders; free list == zero-refcount pages
        exp = self._expected_holders()
        for p in range(self.n_slots, self.n_pages):
            if p in self.quarantined:
                continue
            if self.refcount[p] != exp[p]:
                violations.append(f"refcount: page {p} is "
                                  f"{int(self.refcount[p])}, holders say "
                                  f"{int(exp[p])}")
                implicated.add(p)
            if exp[p] == 0 and p not in seen and p not in implicated:
                violations.append(f"free: page {p} has no holder but is "
                                  "not on the free list")
                implicated.add(p)
        for p in range(self.n_slots):               # scratch never circulates
            if self.refcount[p] != exp[p] or p in seen:
                violations.append(f"scratch: page {p} leaked into "
                                  "circulation")
                implicated.add(p)
        if repair and violations:
            for p in implicated:
                if exp[p] > 0:
                    self.refcount[p] = exp[p]       # holders are the truth
                elif p >= self.n_slots:
                    self.quarantined.add(p)         # no holder: withhold
                    self.refcount[p] = 0
            # rebuild the free list: keep surviving entries in order (free
            # order determines future page assignment), append recovered
            # strays, drop quarantined/held/duplicate entries
            rebuilt, emitted = [], set()
            for p in self.free:
                if p >= self.n_slots and exp[p] == 0 and p not in emitted \
                        and p not in self.quarantined:
                    rebuilt.append(p)
                    emitted.add(p)
            for p in range(self.n_slots, self.n_pages):
                if exp[p] == 0 and p not in emitted \
                        and p not in self.quarantined:
                    rebuilt.append(p)
                    emitted.add(p)
            self.free = deque(rebuilt)
        return violations

    # -- snapshot / restore ---------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serialisable host metadata: block tables, free list,
        allocations, refcounts, and the prefix trie (BFS order, parent
        links).  The device pools are snapshotted separately — together
        they rebuild an identical manager via :meth:`load_state`."""
        nodes: list[dict] = []
        queue = deque([(self._root, -1)])
        while queue:
            node, parent = queue.popleft()
            if node is not self._root:
                nodes.append({
                    "parent": parent,
                    "page": int(node.page),
                    "tokens": np.asarray(node.tokens).ravel().tolist(),
                    "dtype": str(np.asarray(node.tokens).dtype),
                    "last_used": int(node.last_used),
                })
                parent_idx = len(nodes) - 1
            else:
                parent_idx = -1
            for child in node.children.values():    # insertion order kept
                queue.append((child, parent_idx))
        return {
            "n_slots": self.n_slots, "page_size": self.page_size,
            "max_len": self.max_len, "n_pages": self.n_pages,
            "tables": self.tables.tolist(),
            "free": list(self.free),
            "allocated": {str(s): list(p) for s, p in self.allocated.items()},
            "refcount": self.refcount.tolist(),
            "copy_holds": {str(p): h for p, h in self._copy_holds.items()},
            "quarantined": sorted(self.quarantined),
            "clock": self._clock,
            "trie": nodes,
        }

    def load_state(self, state: dict) -> None:
        """Rebuild the manager in place from :meth:`state_dict` output."""
        for field in ("n_slots", "page_size", "max_len", "n_pages"):
            if int(state[field]) != getattr(self, field):
                raise ValueError(f"snapshot {field}={state[field]} does not "
                                 f"match pool ({getattr(self, field)})")
        self.tables = np.asarray(state["tables"], np.int32)
        self.free = deque(int(p) for p in state["free"])
        self.allocated = {int(s): [int(p) for p in pages]
                          for s, pages in state["allocated"].items()}
        self.refcount = np.asarray(state["refcount"], np.int64)
        self._copy_holds = {int(p): int(h)
                            for p, h in state["copy_holds"].items()}
        self.quarantined = {int(p) for p in state.get("quarantined", ())}
        self._clock = int(state["clock"])
        self._root = _TrieNode(None, None, -1, None)
        rebuilt: list[_TrieNode] = []
        for rec in state["trie"]:
            tokens = np.asarray(rec["tokens"], dtype=rec["dtype"])
            key = np.ascontiguousarray(tokens).tobytes()
            parent = self._root if rec["parent"] < 0 \
                else rebuilt[rec["parent"]]
            node = _TrieNode(key, tokens, int(rec["page"]), parent)
            node.last_used = int(rec["last_used"])
            parent.children[key] = node
            rebuilt.append(node)

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def occupancy(self) -> float:
        """Fraction of non-scratch pages currently allocated."""
        usable = self.n_pages - self.n_slots
        return 1.0 - len(self.free) / max(usable, 1)
