"""Continuous-batching serving engine (paged KV cache, mid-stream joins).

Layering::

    traffic      arrival processes (Poisson / batch, shared-prefix pools)
                 -> Request lists
    request      Request / RequestResult accounting
    paged_kv     PagedKVCache — ref-counted page pool + prefix trie +
                 copy-on-write sharing + free list
    scheduler    RequestQueue + Scheduler — ragged requests -> fixed slots
                 (bounded head-of-line skip-ahead, lazy/reserve admission)
    engine       ServeEngine — prefill-on-join (suffix-only on prefix
                 hits), fused masked decode chunks, preemption/requeue on
                 page pressure, free-on-finish, per-request latency +
                 J/token accounting; chaos injection, snapshot/restore
                 (EngineCrash recovery), graceful degradation under
                 emergency caps

See docs/serving_engine.md, docs/prefix_cache.md and
docs/fault_tolerance.md.
"""
from repro.serving.engine import (ChunkStats, EnergyAwareAdmission,
                                  EngineConfig, EngineCrash, EngineReport,
                                  ServeEngine)
from repro.serving.paged_kv import CopySpec, PagedKVCache
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import RequestQueue, Scheduler
from repro.serving.traffic import batch_trace, poisson_trace

__all__ = [
    "ChunkStats", "CopySpec", "EnergyAwareAdmission", "EngineConfig",
    "EngineCrash", "EngineReport", "PagedKVCache", "Request",
    "RequestQueue", "RequestResult", "Scheduler", "ServeEngine",
    "batch_trace", "poisson_trace",
]
