"""Continuous-batching serving engine (paged KV cache, mid-stream joins).

Layering::

    traffic      arrival processes (Poisson / batch) -> Request lists
    request      Request / RequestResult accounting
    paged_kv     PagedKVCache — block tables + free list over page pools
    scheduler    RequestQueue + Scheduler — ragged requests -> fixed slots
    engine       ServeEngine — prefill-on-join, fused masked decode chunks,
                 free-on-finish, per-request latency + J/token accounting

See docs/serving_engine.md.
"""
from repro.serving.engine import (ChunkStats, EnergyAwareAdmission,
                                  EngineConfig, EngineReport, ServeEngine)
from repro.serving.paged_kv import PagedKVCache
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import RequestQueue, Scheduler
from repro.serving.traffic import batch_trace, poisson_trace

__all__ = [
    "ChunkStats", "EnergyAwareAdmission", "EngineConfig", "EngineReport",
    "PagedKVCache", "Request", "RequestQueue", "RequestResult",
    "Scheduler", "ServeEngine", "batch_trace", "poisson_trace",
]
