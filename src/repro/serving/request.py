"""Request objects for the continuous-batching serving engine.

A request is one user's generation job: a ragged prompt plus a token
budget.  The engine clock is counted in *decode steps* (one fused-loop
iteration = one token position across every slot), so arrival times,
waits, and latencies are all expressed in steps — deterministic and
host-speed-independent — with wall-clock seconds recorded alongside.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation job entering the queue."""
    rid: int
    prompt: np.ndarray            # (L,) int32 — or (L, n_cb) multi-codebook
    max_new_tokens: int
    arrival_step: int = 0         # engine decode-step clock
    eos_id: int | None = None     # None: run to max_new_tokens
    priority: int = 0             # higher = preempted later (ties: FIFO)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class RequestResult:
    """Per-request accounting the engine fills in as the request moves
    queue -> slot -> finished."""
    rid: int
    prompt_len: int
    arrival_step: int
    max_new_tokens: int
    admit_step: int = -1          # prefill-on-join step (also first token)
    finish_step: int = -1
    finish_reason: str = ""       # "eos" | "max_new_tokens"
    slot: int = -1
    tokens: list = dataclasses.field(default_factory=list)
    energy_j: float = 0.0         # share of chunk energy, occupied-slots only
    admit_t: float = 0.0          # wall clock, engine-relative seconds
    finish_t: float = 0.0
    n_preemptions: int = 0        # times this request was evicted + requeued
    prefill_tokens_saved: int = 0  # prompt tokens restored from the prefix cache

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def wait_steps(self) -> int:
        """Queueing delay: arrival -> admission (prefill)."""
        return self.admit_step - self.arrival_step

    @property
    def latency_steps(self) -> int:
        """Arrival -> last token, in decode steps."""
        return self.finish_step - self.arrival_step

    @property
    def j_per_token(self) -> float:
        return self.energy_j / max(self.n_tokens, 1)
