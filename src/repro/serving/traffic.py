"""Traffic generators: deterministic arrival processes for the engine.

Real RAN inference traffic (the O-RAN xAPP serving path this repo
reproduces) is a stream of ragged requests, classically modelled as a
Poisson process.  Arrivals are expressed on the engine's decode-step clock
so traces are exactly reproducible on any host speed.

Shared-system-prompt scenarios: real serving traffic overwhelmingly shares
prompt *heads* — system prompts, few-shot headers, RAG boilerplate — which
is exactly what the prefix-sharing page cache exploits.
``shared_prefix_len > 0`` prepends one of ``prompt_pools`` fixed random
prefixes to every request's unique suffix (total prompt length =
``shared_prefix_len`` + the drawn suffix length).  With
``shared_prefix_len=0`` the RNG stream is untouched, so existing traces
are bit-identical to before.
"""
from __future__ import annotations

import numpy as np

from repro.serving.request import Request


def _prompts(rng: np.random.Generator, n: int, lo: int, hi: int,
             vocab_size: int, n_codebooks: int) -> list[np.ndarray]:
    lens = rng.integers(lo, hi + 1, size=n)
    out = []
    for L in lens:
        shape = (int(L), n_codebooks) if n_codebooks else (int(L),)
        out.append(rng.integers(0, vocab_size, size=shape).astype(np.int32))
    return out


def _shared_prefixes(rng: np.random.Generator, prompts: list[np.ndarray],
                     shared_prefix_len: int, prompt_pools: int,
                     vocab_size: int, n_codebooks: int) -> list[np.ndarray]:
    """Prepend a pool-drawn shared prefix to every prompt."""
    shape = (shared_prefix_len, n_codebooks) if n_codebooks \
        else (shared_prefix_len,)
    pools = [rng.integers(0, vocab_size, size=shape).astype(np.int32)
             for _ in range(max(prompt_pools, 1))]
    picks = rng.integers(0, len(pools), size=len(prompts))
    return [np.concatenate([pools[picks[i]], p], axis=0)
            for i, p in enumerate(prompts)]


def poisson_trace(n_requests: int, *, rate_per_step: float, seed: int,
                  vocab_size: int, prompt_len: tuple[int, int],
                  max_new_tokens: tuple[int, int], n_codebooks: int = 0,
                  eos_id: int | None = None, shared_prefix_len: int = 0,
                  prompt_pools: int = 1) -> list[Request]:
    """Poisson arrivals: exponential inter-arrival gaps with mean
    ``1 / rate_per_step`` decode steps; ragged prompt lengths and token
    budgets drawn uniformly from the given inclusive ranges.  With
    ``shared_prefix_len > 0``, ``prompt_len`` bounds the *unique suffix*
    and every prompt is ``shared_prefix + suffix``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_per_step, 1e-9), size=n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    prompts = _prompts(rng, n_requests, *prompt_len, vocab_size, n_codebooks)
    gens = rng.integers(max_new_tokens[0], max_new_tokens[1] + 1,
                        size=n_requests)
    if shared_prefix_len > 0:
        prompts = _shared_prefixes(rng, prompts, shared_prefix_len,
                                   prompt_pools, vocab_size, n_codebooks)
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=int(gens[i]),
                    arrival_step=int(arrivals[i]), eos_id=eos_id)
            for i in range(n_requests)]


def batch_trace(n_requests: int, *, seed: int, vocab_size: int,
                prompt_len: int, max_new_tokens: int, n_codebooks: int = 0,
                eos_id: int | None = None, shared_prefix_len: int = 0,
                prompt_pools: int = 1) -> list[Request]:
    """Everything arrives at step 0 with uniform shape — the static-batch
    baseline expressed as a trace.  ``shared_prefix_len`` prepends pooled
    shared heads exactly as in :func:`poisson_trace`."""
    rng = np.random.default_rng(seed)
    prompts = _prompts(rng, n_requests, prompt_len, prompt_len,
                       vocab_size, n_codebooks)
    if shared_prefix_len > 0:
        prompts = _shared_prefixes(rng, prompts, shared_prefix_len,
                                   prompt_pools, vocab_size, n_codebooks)
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=max_new_tokens,
                    arrival_step=0, eos_id=eos_id)
            for i in range(n_requests)]
