"""Continuous-batching serving engine over the paged KV cache.

Converts the one-shot batch serving path into a stateful multi-request
loop: ragged requests join fixed decode slots mid-stream (prefill-on-join),
decode runs in fused chunks of ``decode_chunk`` tokens over ALL slots with
a per-slot validity mask (one AOT executable for every occupancy pattern),
and slots free on EOS / token budget at harvest, at chunk granularity.

Anatomy of one engine cycle::

    poll ──> prefill-on-join ──> sync tables/pos ──> fused chunk ──> harvest
     ^   (bucketed prompt,        (host mirrors       (paged loop,     │
     │    pages injected)          -> device)          donated cache)  │
     └──────────────────── free slots / pages on finish ───────────────┘

Telemetry: the engine itself is control-plane-agnostic — the launcher
passes an ``on_chunk`` hook that receives per-chunk :class:`ChunkStats`
(measured wall time, occupancy, useful-vs-computed tokens) and returns the
chunk's energy in joules (or ``None``).  Energy is attributed to requests
in proportion to their *kept* tokens, so J/token charges only occupied
slots — utilisation-honest under partial occupancy.

Speculative mode (``EngineConfig.spec_k > 0``): each chunk iteration
becomes a K+1-token verify step (draft -> verify -> accept -> commit,
in-scan, per-slot accepted counts — see docs/speculative_decoding.md), the
harvest consumes a *variable* number of tokens per slot per step, and the
report adds acceptance rate and J per *accepted* token, with rejected
drafts' compute charged as overhead.  The per-slot drafter history is one
more host mirror, seeded at prefill-on-join.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.runtime.speculate import get_drafter
from repro.runtime.steps import (StepConfig, make_paged_decode_loop,
                                 make_paged_speculative_decode_loop,
                                 make_run_ctx)
from repro.serving.paged_kv import PagedKVCache
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import RequestQueue, Scheduler


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs orthogonal to the model config."""
    n_slots: int = 4
    page_size: int = 16
    max_len: int = 256            # per-request prompt + generation ceiling
    decode_chunk: int = 8
    n_pages: int | None = None    # None: fully provisioned (no page waits)
    greedy: bool = True
    temperature: float = 1.0
    sample_seed: int = 0
    cache_dtype: str = "bfloat16"
    min_prefill_bucket: int = 8   # prompts pad up to pow2 buckets >= this
    # speculative decoding: >0 turns each chunk iteration into a K+1-token
    # verify step (draft -> verify -> accept in-scan, per-slot counts)
    spec_k: int = 0
    drafter: str = "ngram"        # ngram | repeat (self-drafters)
    drafter_hist: int = 128       # ngram lookup history per slot


@dataclasses.dataclass(frozen=True)
class ChunkStats:
    """One fused chunk's telemetry, handed to the ``on_chunk`` hook."""
    step: int                     # chunk index
    wall_s: float                 # measured execution time (compile excluded)
    n_slots: int
    n_active: int                 # slots holding a live request
    tokens_kept: int              # useful tokens harvested this chunk
    tokens_computed: int          # n_active * chunk * (K+1) (incl. overrun)
    drafts_proposed: int = 0      # speculative mode only
    drafts_accepted: int = 0


@dataclasses.dataclass
class EngineReport:
    """Run summary + per-request results.

    Ratio properties are guarded against empty runs (zero requests, zero
    kept tokens, zero wall) — they return 0.0 rather than leaking NaN /
    inf into benchmark CSVs."""
    results: list[RequestResult]
    n_chunks: int = 0
    decode_wall_s: float = 0.0
    prefill_wall_s: float = 0.0
    tokens_kept: int = 0
    tokens_computed: int = 0
    energy_j: float = 0.0
    occupancy: float = 0.0        # mean active/slots over chunks
    spec_k: int = 0               # 0 = plain decode
    drafts_proposed: int = 0
    drafts_accepted: int = 0

    @property
    def tok_per_s(self) -> float:
        if self.tokens_kept <= 0 or self.decode_wall_s <= 0.0:
            return 0.0
        return self.tokens_kept / self.decode_wall_s

    @property
    def j_per_token(self) -> float:
        """Charges only tokens actually served — under partial occupancy
        this is the honest (higher) figure.  In speculative mode the kept
        tokens are the *accepted* ones, so rejected drafts' compute lands
        here as overhead (see ``j_per_accepted_token``)."""
        if self.tokens_kept <= 0:
            return 0.0
        return self.energy_j / self.tokens_kept

    @property
    def acceptance_rate(self) -> float:
        """Accepted / proposed drafts (0.0 when not speculating)."""
        if self.drafts_proposed <= 0:
            return 0.0
        return self.drafts_accepted / self.drafts_proposed

    @property
    def j_per_accepted_token(self) -> float:
        """The speculative serving figure of merit: every kept token is an
        accepted draft or the verify step's bonus token, and the chunk's
        full energy — including the sweeps spent scoring rejected drafts —
        is in the numerator.  Identical to ``j_per_token`` by construction;
        named so reports say what is being charged."""
        return self.j_per_token

    @property
    def tokens_per_step(self) -> float:
        """Mean useful tokens per slot-step — the effective-throughput
        multiplier admission control should see under speculation."""
        if self.n_chunks <= 0 or self.tokens_computed <= 0:
            return 0.0
        steps = self.tokens_computed / max(self.spec_k + 1, 1)
        return self.tokens_kept / max(steps, 1e-9)

    def latency_percentiles(self, qs=(50, 95)) -> dict[int, float]:
        lats = [r.latency_steps for r in self.results if r.finish_step >= 0]
        if not lats:
            return {q: 0.0 for q in qs}    # no finished requests: keep CSVs finite
        return {q: float(np.percentile(lats, q)) for q in qs}


class EnergyAwareAdmission:
    """Admission hook: admit while the predicted board draw at the
    *resulting* occupancy — under the cap currently in force — stays within
    a power budget.  Under a deep cap decode is memory-bound and occupancy
    is near-free, so the hook admits aggressively; at high caps it backs
    off, which is exactly the paper's serving trade expressed as admission
    control."""

    def __init__(self, device, workload_fn: Callable[[int], object],
                 budget_w: float, backend=None):
        self.device = device
        self.workload_fn = workload_fn        # n_active -> WorkloadProfile
        self.budget_w = float(budget_w)
        self.backend = backend                # CapBackend (current_cap())

    def __call__(self, request: Request, n_active_after: int) -> bool:
        cap = self.backend.current_cap() if self.backend is not None else 1.0
        est = self.device.estimate(self.workload_fn(n_active_after), cap)
        return est.power_w <= self.budget_w


class ServeEngine:
    """Drives the fused paged decode loop over live slots."""

    def __init__(self, cfg, engine_cfg: EngineConfig, params, *,
                 step_cfg: StepConfig | None = None, rules=None,
                 on_chunk: Callable[[ChunkStats], float | None] | None = None,
                 admission=None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.params = params
        self.step_cfg = step_cfg or StepConfig(remat="none")
        self.rules = rules
        self.on_chunk = on_chunk
        self.kv = PagedKVCache(cfg, n_slots=engine_cfg.n_slots,
                               page_size=engine_cfg.page_size,
                               max_len=engine_cfg.max_len,
                               n_pages=engine_cfg.n_pages,
                               dtype=engine_cfg.cache_dtype)
        self.scheduler = Scheduler(engine_cfg.n_slots, self.kv,
                                   admission=admission)
        self.cache = self.kv.make_cache()
        self._ctx = make_run_ctx(cfg, rules, self.step_cfg)
        self._loop = None                    # AOT-compiled paged chunk loop
        self._prefills: dict[int, object] = {}   # bucket -> compiled prefill
        self._injects: dict[int, object] = {}    # bucket -> compiled inject
        self._pos = np.zeros((engine_cfg.n_slots,), np.int32)
        self._sample_key = jax.random.PRNGKey(engine_cfg.sample_seed)
        self._drafter = None
        self._dstate = None
        if engine_cfg.spec_k > 0:
            if not tfm.supports_speculative(cfg):
                raise ValueError(f"{cfg.name}: speculative serving needs a "
                                 "dense GQA family")
            self._drafter = get_drafter(engine_cfg.drafter, engine_cfg.spec_k,
                                        hist_len=engine_cfg.drafter_hist)
            # host mirror of the per-slot drafter state, synced like
            # pos/block_tables: seeded at prefill-on-join, carried through
            # the fused loop, read back at harvest
            self._dstate = self._drafter.init_state(engine_cfg.n_slots)

    # -- compiled pieces (AOT so compile time never lands in measured walls) -
    def _chunk_loop(self, *args):
        if self._loop is None:
            if self._drafter is not None:
                fn = jax.jit(make_paged_speculative_decode_loop(
                    self.cfg, self.step_cfg, self.rules,
                    self.ecfg.decode_chunk, drafter=self._drafter,
                    greedy=self.ecfg.greedy,
                    temperature=self.ecfg.temperature), donate_argnums=(1,))
            else:
                fn = jax.jit(make_paged_decode_loop(
                    self.cfg, self.step_cfg, self.rules,
                    self.ecfg.decode_chunk, greedy=self.ecfg.greedy,
                    temperature=self.ecfg.temperature), donate_argnums=(1,))
            self._loop = fn.lower(*args).compile()
        return self._loop

    def _prefill(self, bucket: int):
        if bucket not in self._prefills:
            cfg, ctx = self.cfg, self._ctx

            def prefill(params, inputs):
                return tfm.prefill(params, inputs, cfg, ctx, max_len=bucket)

            self._prefills[bucket] = jax.jit(prefill)
        return self._prefills[bucket]

    def _inject(self, bucket: int):
        """Scatter a (padded) prefill cache into a slot's pages: one fused
        donated update across every unit pool, keyed by flat row ids from
        ``PagedKVCache.inject_rows`` (pad rows dropped)."""
        if bucket not in self._injects:
            def inject(cache, prefill_units, rows):
                units = {}
                for name, c in cache["units"].items():
                    src, new = prefill_units[name], {}
                    for key in ("k", "v"):
                        pool = c[key]                # (nu, P, ps, hkv, hd)
                        nu = pool.shape[0]
                        flat = pool.reshape(nu, -1, *pool.shape[3:])
                        flat = flat.at[:, rows].set(
                            src[key][:, 0].astype(flat.dtype), mode="drop")
                        new[key] = flat.reshape(pool.shape)
                    units[name] = new
                return {**cache, "units": units}

            self._injects[bucket] = jax.jit(inject, donate_argnums=(0,))
        return self._injects[bucket]

    def _bucket(self, L: int) -> int:
        b = self.ecfg.min_prefill_bucket
        while b < L:
            b *= 2
        return b

    # -- join ----------------------------------------------------------------
    def _sample_first(self, logits_row, rid: int):
        """Sample the prefill's token (greedy or temperature) — position
        prompt_len - 1 of the padded prefill logits."""
        if self.ecfg.greedy:
            return np.asarray(jnp.argmax(logits_row, axis=-1), np.int32)
        key = jax.random.fold_in(self._sample_key, (rid << 1) | 1)
        nxt = jax.random.categorical(
            key, logits_row / self.ecfg.temperature, axis=-1)
        return np.asarray(nxt, np.int32)

    def _join(self, slot: int, req: Request, t0: float) -> None:
        L = req.prompt_len
        if L + req.max_new_tokens > self.ecfg.max_len:
            raise ValueError(f"request {req.rid}: prompt {L} + "
                             f"{req.max_new_tokens} new > max_len "
                             f"{self.ecfg.max_len}")
        bucket = self._bucket(L)
        pad_shape = (1, bucket - L) + req.prompt.shape[1:]
        inputs = np.concatenate(
            [req.prompt[None], np.zeros(pad_shape, np.int32)], axis=1)
        logits, pcache = self._prefill(bucket)(self.params,
                                               jnp.asarray(inputs))
        first = self._sample_first(logits[0, L - 1], req.rid)
        rows = jnp.asarray(self.kv.inject_rows(slot, bucket, L))
        self.cache = self._inject(bucket)(self.cache, pcache["units"], rows)
        self._pos[slot] = L
        if self._drafter is not None:
            self._drafter.seed_request(self._dstate, slot, req.prompt, first)
        state = self.scheduler.slots[slot]
        state.next_token = first
        res = self._results[req.rid]
        res.slot = slot
        res.admit_step = self._now
        res.admit_t = time.perf_counter() - t0
        res.tokens.append(first.tolist() if first.ndim else int(first))
        if req.eos_id is not None and first.ndim == 0 \
                and int(first) == req.eos_id:
            state.remaining = 0
            res.finish_reason = "eos"
        if state.remaining <= 0:                  # max_new 1, or instant EOS
            res.finish_reason = res.finish_reason or "max_new_tokens"
            res.finish_step = self._now
            res.finish_t = time.perf_counter() - t0
            self.scheduler.finish(slot)
            self._pos[slot] = 0

    # -- harvest -------------------------------------------------------------
    def _harvest(self, toks: np.ndarray, t0: float) -> dict[int, int]:
        """Plain harvest — exactly the speculative harvest where every step
        yielded one token.  toks: (n_slots, chunk[, n_cb])."""
        counts = np.ones(toks.shape[:2], np.int32)
        return self._harvest_spec(toks[:, :, None], counts, t0)

    def _harvest_spec(self, toks: np.ndarray, counts: np.ndarray,
                      t0: float) -> dict[int, int]:
        """Append each active slot's kept tokens, finish on EOS / budget.

        Each step yields ``counts[slot, s]`` tokens (1 on the plain path;
        accepted drafts + the bonus token, 1..K+1, when speculating) —
        consumed in order at chunk granularity.  Returns kept (useful)
        token counts per request id for this chunk — the
        energy-attribution weights.  toks: (n_slots, steps, K+1[, n_cb])."""
        kept_by_rid: dict[int, int] = {}
        for slot in self.scheduler.active_slots():
            state = self.scheduler.slots[slot]
            req = state.request
            res = self._results[req.rid]
            kept = 0
            budget = state.remaining
            for s in range(toks.shape[1]):
                if res.finish_reason == "eos" or kept >= budget:
                    break
                for i in range(int(counts[slot, s])):
                    t = toks[slot, s, i]
                    res.tokens.append(t.tolist() if t.ndim else int(t))
                    kept += 1
                    if req.eos_id is not None and t.ndim == 0 \
                            and int(t) == req.eos_id:
                        res.finish_reason = "eos"
                        break
                    if kept >= budget:
                        break
            kept_by_rid[req.rid] = kept
            state.remaining = 0 if res.finish_reason == "eos" \
                else state.remaining - kept
            # the loop's carried token: last emitted token of the last step
            state.next_token = toks[slot, -1, max(int(counts[slot, -1]) - 1, 0)]
            if state.remaining == 0:
                res.finish_reason = res.finish_reason or "max_new_tokens"
                res.finish_step = self._now + self.ecfg.decode_chunk
                res.finish_t = time.perf_counter() - t0
                self.scheduler.finish(slot)
                self._pos[slot] = 0
        return kept_by_rid

    # -- main loop -----------------------------------------------------------
    def run(self, requests: list[Request]) -> EngineReport:
        ecfg = self.ecfg
        queue = RequestQueue(requests)
        self._results = {r.rid: RequestResult(
            rid=r.rid, prompt_len=r.prompt_len, arrival_step=r.arrival_step,
            max_new_tokens=r.max_new_tokens) for r in requests}
        self._now = 0
        report = EngineReport(results=[], spec_k=ecfg.spec_k)
        occ_sum = 0.0
        t0 = time.perf_counter()
        n_cb = self.cfg.n_codebooks
        tok_shape = (ecfg.n_slots, 1) + ((n_cb,) if n_cb else ())
        tok_in = np.zeros(tok_shape, np.int32)
        chunk_idx = 0

        while len(queue) or self.scheduler.n_active:
            t_p = time.perf_counter()
            for slot, req in self.scheduler.poll(queue, self._now):
                self._join(slot, req, t0)
            report.prefill_wall_s += time.perf_counter() - t_p

            if self.scheduler.n_active == 0:
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                if nxt <= self._now:
                    # the head request is due but poll refused it on an EMPTY
                    # engine: pages/admission can never be satisfied — fail
                    # loudly instead of spinning the idle branch forever
                    raise RuntimeError(
                        f"request {queue.peek_ready(self._now).rid} not "
                        f"admissible at zero load (pool {self.kv.n_pages} "
                        "pages / admission hook); raise n_pages or the "
                        "power budget")
                self._now = nxt                   # idle: jump to next arrival
                continue

            active = np.zeros((ecfg.n_slots,), np.int32)
            for slot in self.scheduler.active_slots():
                active[slot] = 1
                tok_in[slot, 0] = self.scheduler.slots[slot].next_token
            # sync host mirrors (membership may have changed since last chunk)
            self.cache = {**self.cache,
                          "pos": jnp.asarray(self._pos),
                          "block_tables": jnp.asarray(self.kv.tables)}
            spec = self._drafter is not None
            args = [self.params, self.cache, jnp.asarray(tok_in),
                    jnp.asarray(active)]
            if spec:
                args.append({k: jnp.asarray(v)
                             for k, v in self._dstate.items()})
            if not ecfg.greedy:
                # even namespace: first-token keys live at (rid << 1) | 1
                args.append(jax.random.fold_in(self._sample_key,
                                               chunk_idx << 1))
            loop = self._chunk_loop(*args)
            t_c = time.perf_counter()
            if spec:
                toks, counts, self.cache, dstate = loop(*args)
                toks = np.asarray(jax.block_until_ready(toks))
                counts = np.asarray(counts)
                # np.array (not asarray): seed_row mutates this mirror on join
                self._dstate = {k: np.array(v) for k, v in dstate.items()}
            else:
                toks, self.cache = loop(*args)
                toks = np.asarray(jax.block_until_ready(toks))
            wall = time.perf_counter() - t_c

            n_active = int(active.sum())
            if spec:
                # device pos advanced by this chunk's per-slot emitted counts
                self._pos += counts.sum(axis=1).astype(np.int32)
                kept_by_rid = self._harvest_spec(toks, counts, t0)
                kept = sum(kept_by_rid.values())
                computed = n_active * ecfg.decode_chunk * (ecfg.spec_k + 1)
                proposed = n_active * ecfg.decode_chunk * ecfg.spec_k
                accepted = int(counts.sum()) - n_active * ecfg.decode_chunk
            else:
                self._pos[active.astype(bool)] += ecfg.decode_chunk
                kept_by_rid = self._harvest(toks, t0)
                kept = sum(kept_by_rid.values())
                computed = n_active * ecfg.decode_chunk
                proposed = accepted = 0
            self._now += ecfg.decode_chunk
            chunk_idx += 1

            stats = ChunkStats(step=chunk_idx, wall_s=wall,
                               n_slots=ecfg.n_slots, n_active=n_active,
                               tokens_kept=kept, tokens_computed=computed,
                               drafts_proposed=proposed,
                               drafts_accepted=accepted)
            energy = self.on_chunk(stats) if self.on_chunk is not None else None
            report.n_chunks += 1
            report.decode_wall_s += wall
            report.tokens_kept += kept
            report.tokens_computed += stats.tokens_computed
            report.drafts_proposed += proposed
            report.drafts_accepted += accepted
            occ_sum += n_active / ecfg.n_slots
            if energy:
                report.energy_j += energy
                # charge occupied slots only, pro rata by kept tokens
                for rid, n in kept_by_rid.items():
                    if n > 0:
                        self._results[rid].energy_j += energy * n / max(kept, 1)

        report.occupancy = occ_sum / max(report.n_chunks, 1)
        report.results = [self._results[r.rid] for r in requests]
        return report
