"""Continuous-batching serving engine over the paged KV cache.

Converts the one-shot batch serving path into a stateful multi-request
loop: ragged requests join fixed decode slots mid-stream (prefill-on-join),
decode runs in fused chunks of ``decode_chunk`` tokens over ALL slots with
a per-slot validity mask (one AOT executable for every occupancy pattern),
and slots free on EOS / token budget at harvest, at chunk granularity.

Anatomy of one engine cycle::

    poll ──> prefill-on-join ──> grow/preempt ──> fused chunk ──> harvest
     ^   (cached prefix shared,   (lazy pages;     (paged loop,      │
     │    only the suffix runs)    requeue on       donated cache)   │
     │                             pressure)                         │
     └──────────────────── free slots / pages on finish ─────────────┘

Joins with a prefix-cache hit map shared read-only pages and prefill only
the uncached suffix (chunked, through the paged verify sweep — see
docs/prefix_cache.md); cold prompts keep the classic bucketed prefill +
page inject.  In lazy mode (``EngineConfig.preempt``) pages grow
chunk-by-chunk and page pressure evicts the lowest-priority slot back to
the queue instead of stalling admission.

Telemetry: the engine itself is control-plane-agnostic — the launcher
passes an ``on_chunk`` hook that receives per-chunk :class:`ChunkStats`
(measured wall time, occupancy, useful-vs-computed tokens) and returns the
chunk's energy in joules (or ``None``).  Energy is attributed to requests
in proportion to their *kept* tokens, so J/token charges only occupied
slots — utilisation-honest under partial occupancy.

KV storage is tiered (``EngineConfig.kv_dtype`` / ``host_tier``): int8
pages with per-row fp32 scales quarter the device footprint of a page
(dequant fused into the decode sweeps), and cold prefix-cache pages can
demote to a host-memory pool instead of being dropped — paged back in on
the next prefix hit, with the modelled D2H/H2D energy charged into the
same J/token ledger (see docs/prefix_cache.md, "KV memory hierarchy").

Speculative mode (``EngineConfig.spec_k > 0``): each chunk iteration
becomes a K+1-token verify step (draft -> verify -> accept -> commit,
in-scan, per-slot accepted counts — see docs/speculative_decoding.md), the
harvest consumes a *variable* number of tokens per slot per step, and the
report adds acceptance rate and J per *accepted* token, with rejected
drafts' compute charged as overhead.  The per-slot drafter history is one
more host mirror, seeded at prefill-on-join.
"""
from __future__ import annotations

import dataclasses
import json
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.checkpoint.store import CheckpointManager, restore_pytree
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.runtime.chaos import FaultInjector, corrupt_paged_kv
from repro.runtime.speculate import get_drafter
from repro.runtime.steps import (StepConfig, make_paged_decode_loop,
                                 make_paged_speculative_decode_loop,
                                 make_prefill_suffix_step, make_run_ctx,
                                 with_decode_policy)
from repro.serving.paged_kv import PagedKVCache
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import RequestQueue, Scheduler


class EngineCrash(RuntimeError):
    """Injected engine-process death (chaos drills).  Carries the decode
    step at which the engine died so recovery latency can be reported;
    callers recover via ``ServeEngine.restore`` + ``resume``."""

    def __init__(self, step: int):
        super().__init__(f"engine crashed at step {step}")
        self.step = int(step)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs orthogonal to the model config."""
    n_slots: int = 4
    page_size: int = 16
    max_len: int = 256            # per-request prompt + generation ceiling
    decode_chunk: int = 8
    n_pages: int | None = None    # None: fully provisioned (no page waits)
    greedy: bool = True
    temperature: float = 1.0
    sample_seed: int = 0
    cache_dtype: str = "bfloat16"
    min_prefill_bucket: int = 8   # prompts pad up to pow2 buckets >= this
    # speculative decoding: >0 turns each chunk iteration into a K+1-token
    # verify step (draft -> verify -> accept in-scan, per-slot counts)
    spec_k: int = 0
    drafter: str = "ngram"        # ngram | repeat (self-drafters)
    drafter_hist: int = 128       # ngram lookup history per slot
    # prefix sharing: admit_with_prefix maps cached prompt prefixes onto
    # shared read-only pages and only the uncached suffix is prefilled
    # (chunked, through the paged verify sweep).  Families whose pages ride
    # the main block tables only (dense/MoE GQA and MLA, deepseek's first
    # dense layers included); silently disabled elsewhere — windowed rings,
    # SSM state slots and multi-codebook keep the legacy cold-prefill path.
    prefix_cache: bool = True
    prefill_chunk: int = 16       # suffix tokens per chunked-prefill sweep
    # preemption: admit on prompt pages only, grow per chunk, and when the
    # pool runs dry evict the lowest-priority slot and re-queue it with
    # its generated tokens folded into the prompt (the prefix cache then
    # mostly restores the requeue for free).  False = reserve the whole
    # context at admission (the old hard-stall behaviour).
    preempt: bool = True
    # head-of-line fix: when the queue head cannot get pages, try up to
    # this many ready requests behind it (admitted order stays FIFO
    # otherwise)
    max_skip: int = 2
    # decode-sweep operating point: two-stage split-KV count ("auto" = the
    # ops.choose_kv_splits occupancy heuristic; 1 = single-stage sweep) and
    # the split-K block for the ring kernels / page-sized DMA elsewhere
    kv_splits: str | int = "auto"
    decode_k_chunk: int = 256
    # quantized KV pages: "int8" stores every page pool as int8 with
    # per-row fp32 scales and the dequant fused into the split-KV sweeps
    # (see docs/prefix_cache.md, "KV memory hierarchy").  Dense-GQA
    # families only — elsewhere the engine warns once (RuntimeWarning) and
    # keeps cache_dtype.  The default leaves the decode path byte-identical
    # to unquantized serving.
    kv_dtype: str = "bfloat16"
    # host-memory page-out: cold trie-held pages demote to a host pool
    # instead of being dropped, and a later prefix hit pages them back in.
    # Each direction is charged at transfer_j_per_byte into the energy
    # ledger; with recompute_j_per_token set, a page is only demoted when
    # the round trip is cheaper than re-prefilling its rows.
    host_tier: bool = False
    host_pages: int | None = None       # None: unbounded host pool
    transfer_j_per_byte: float = 1e-9
    recompute_j_per_token: float | None = None


@dataclasses.dataclass(frozen=True)
class ChunkStats:
    """One fused chunk's telemetry, handed to the ``on_chunk`` hook."""
    step: int                     # chunk index
    wall_s: float                 # measured execution time (compile excluded)
    n_slots: int
    n_active: int                 # slots holding a live request
    tokens_kept: int              # useful tokens harvested this chunk
    tokens_computed: int          # n_active * chunk * (K+1) (incl. overrun)
    drafts_proposed: int = 0      # speculative mode only
    drafts_accepted: int = 0
    clock_step: int = 0           # engine decode-step clock at chunk end
    degrade_level: int = 0        # 0 healthy, 1 derate, 2 emergency cap


@dataclasses.dataclass
class EngineReport:
    """Run summary + per-request results.

    Ratio properties are guarded against empty runs (zero requests, zero
    kept tokens, zero wall) — they return 0.0 rather than leaking NaN /
    inf into benchmark CSVs."""
    results: list[RequestResult]
    n_chunks: int = 0
    decode_wall_s: float = 0.0
    prefill_wall_s: float = 0.0
    tokens_kept: int = 0
    tokens_computed: int = 0
    energy_j: float = 0.0
    occupancy: float = 0.0        # mean active/slots over chunks
    spec_k: int = 0               # 0 = plain decode
    drafts_proposed: int = 0
    drafts_accepted: int = 0
    prompt_tokens: int = 0        # prompt tokens across every join (requeues too)
    prefill_tokens_saved: int = 0  # restored from the prefix cache, not computed
    n_preemptions: int = 0        # slots evicted + re-queued on page pressure
    # fault-tolerance accounting (docs/fault_tolerance.md)
    n_faults_injected: int = 0    # chaos faults applied to this engine
    n_restores: int = 0           # crash-restores this report survived
    degraded_steps: int = 0       # clock steps spent degraded (derate/cap)
    requeued_requests: int = 0    # in-flight requests recovered via requeue
    n_pages_quarantined: int = 0  # pages withheld after corruption repair
    # two-tier KV hierarchy: modelled page-out/page-in energy (already
    # included in energy_j; broken out so benchmarks can see the split)
    transfer_j: float = 0.0
    n_demotions: int = 0          # device pages paged out to the host tier
    n_promotions: int = 0         # host pages paged back in on a prefix hit

    @property
    def tok_per_s(self) -> float:
        if self.tokens_kept <= 0 or self.decode_wall_s <= 0.0:
            return 0.0
        return self.tokens_kept / self.decode_wall_s

    @property
    def j_per_token(self) -> float:
        """Charges only tokens actually served — under partial occupancy
        this is the honest (higher) figure.  In speculative mode the kept
        tokens are the *accepted* ones, so rejected drafts' compute lands
        here as overhead (see ``j_per_accepted_token``)."""
        if self.tokens_kept <= 0:
            return 0.0
        return self.energy_j / self.tokens_kept

    @property
    def acceptance_rate(self) -> float:
        """Accepted / proposed drafts (0.0 when not speculating)."""
        if self.drafts_proposed <= 0:
            return 0.0
        return self.drafts_accepted / self.drafts_proposed

    @property
    def j_per_accepted_token(self) -> float:
        """The speculative serving figure of merit: every kept token is an
        accepted draft or the verify step's bonus token, and the chunk's
        full energy — including the sweeps spent scoring rejected drafts —
        is in the numerator.  Identical to ``j_per_token`` by construction;
        named so reports say what is being charged."""
        return self.j_per_token

    @property
    def tokens_per_step(self) -> float:
        """Mean useful tokens per slot-step — the effective-throughput
        multiplier admission control should see under speculation."""
        if self.n_chunks <= 0 or self.tokens_computed <= 0:
            return 0.0
        steps = self.tokens_computed / max(self.spec_k + 1, 1)
        return self.tokens_kept / max(steps, 1e-9)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens restored from the prefix cache
        instead of being prefilled (0.0 on empty runs)."""
        if self.prompt_tokens <= 0:
            return 0.0
        return self.prefill_tokens_saved / self.prompt_tokens

    def latency_percentiles(self, qs=(50, 95)) -> dict[int, float]:
        lats = [r.latency_steps for r in self.results if r.finish_step >= 0]
        if not lats:
            return {q: 0.0 for q in qs}    # no finished requests: keep CSVs finite
        return {q: float(np.percentile(lats, q)) for q in qs}


class EnergyAwareAdmission:
    """Admission hook: admit while the predicted board draw at the
    *resulting* occupancy — under the cap currently in force — stays within
    a power budget.  Under a deep cap decode is memory-bound and occupancy
    is near-free, so the hook admits aggressively; at high caps it backs
    off, which is exactly the paper's serving trade expressed as admission
    control."""

    def __init__(self, device, workload_fn: Callable[[int], object],
                 budget_w: float, backend=None):
        self.device = device
        self.workload_fn = workload_fn        # n_active -> WorkloadProfile
        self.budget_w = float(budget_w)
        self.backend = backend                # CapBackend (current_cap())

    def __call__(self, request: Request, n_active_after: int) -> bool:
        cap = self.backend.current_cap() if self.backend is not None else 1.0
        est = self.device.estimate(self.workload_fn(n_active_after), cap)
        return est.power_w <= self.budget_w


class ServeEngine:
    """Drives the fused paged decode loop over live slots."""

    def __init__(self, cfg, engine_cfg: EngineConfig, params, *,
                 step_cfg: StepConfig | None = None, rules=None,
                 on_chunk: Callable[[ChunkStats], float | None] | None = None,
                 on_prefill: Callable[[int, int], float | None] | None = None,
                 admission=None,
                 injector: FaultInjector | None = None,
                 on_heartbeat: Callable[[int, float], None] | None = None,
                 on_fault: Callable[[object], None] | None = None,
                 snapshot_dir: str | None = None, snapshot_every: int = 0):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.params = params
        # quantized KV pages pair per-row scale leaves with full-length k/v
        # page pools; families whose pools are latent rows, private rings or
        # state slots warn once (naming the blocking feature) and keep the
        # unquantized layout
        kv_dtype = engine_cfg.cache_dtype
        if engine_cfg.kv_dtype == "int8":
            i8_block = tfm.int8_paged_blockers(cfg)
            if not i8_block:
                kv_dtype = "int8"
            else:
                ops.warn_kv_dtype_fallback(
                    cfg.name, f"int8 paged cache blocked by {i8_block[0]}")
        self.kv_dtype = kv_dtype
        # engine config owns the decode-sweep operating point: fold it onto
        # the kernel policy so every compiled loop (decode, verify, suffix
        # prefill) sees the same kv_splits / block / storage-dtype choice
        self.step_cfg = with_decode_policy(
            step_cfg or StepConfig(remat="none"),
            kv_splits=engine_cfg.kv_splits,
            decode_k_chunk=engine_cfg.decode_k_chunk,
            kv_dtype=kv_dtype)
        self.rules = rules
        self.on_chunk = on_chunk
        # on_prefill(n_computed, n_saved) -> J for one join's prefill (or
        # None): lets the launcher charge prefill compute into the same
        # J/token ledger — and see the joules the prefix cache avoided
        self.on_prefill = on_prefill
        # chaos: faults polled on the decode-step clock each cycle; kinds
        # the engine cannot act on itself (bus_drop/bus_delay) forward to
        # on_fault so the launcher can disturb its own telemetry transport
        self.injector = injector
        # on_heartbeat(clock_step, chunk_wall_s): liveness signal for a
        # serving supervisor; suppressed while a "stall" fault is active
        self.on_heartbeat = on_heartbeat
        self.on_fault = on_fault
        self.snapshot_every = int(snapshot_every)
        self._ckpt = CheckpointManager(snapshot_dir, keep=2) \
            if snapshot_dir else None
        self.kv = PagedKVCache(cfg, n_slots=engine_cfg.n_slots,
                               page_size=engine_cfg.page_size,
                               max_len=engine_cfg.max_len,
                               n_pages=engine_cfg.n_pages,
                               dtype=kv_dtype,
                               host_tier=engine_cfg.host_tier,
                               host_pages=engine_cfg.host_pages,
                               transfer_j_per_byte=engine_cfg.transfer_j_per_byte,
                               recompute_j_per_token=engine_cfg.recompute_j_per_token)
        # prefix sharing rides the chunked-prefill verify seam (suffix
        # chunks are scored by paged_verify_attention): any family whose
        # pages live on the main block tables qualifies — dense/MoE GQA and
        # MLA (deepseek's first dense layers included).  Windowed rings,
        # SSM state slots and the hybrid shared buffer keep the cold path.
        self._use_prefix = (engine_cfg.prefix_cache
                            and not tfm.chunked_prefill_blockers(cfg))
        self.scheduler = Scheduler(engine_cfg.n_slots, self.kv,
                                   admission=admission,
                                   max_skip=engine_cfg.max_skip,
                                   lazy=engine_cfg.preempt,
                                   prefix=self._use_prefix)
        self.cache = self.kv.make_cache()
        # unit subs whose page axis rides the main block tables — the only
        # pools the page-granular seams (host tier, CoW copy, page bytes)
        # may touch.  Windowed rings / SSM state slots are slot-indexed on
        # axis 1, so treating them as pages would corrupt other slots.
        self._table_subs = frozenset(
            f"sub{i}" for i in range(tfm.unit_size(cfg))
            if not cfg.uses_ssm
            and (cfg.use_mla or cfg.window_for_layer(i) == 0))
        self._tier_restore = None            # AOT page-in scatter (H2D)
        self._transfer_seen = 0.0            # kv.transfer_j folded so far
        if engine_cfg.host_tier:
            if self.kv.tables_active:
                self.kv.attach_tier(self._fetch_page, self._restore_page,
                                    self._cache_page_bytes())
            else:
                warnings.warn(
                    f"config {cfg.name!r}: host KV tier disabled: no page "
                    "pool rides the block tables (state-slot layout)",
                    RuntimeWarning, stacklevel=2)
        self._ctx = make_run_ctx(cfg, rules, self.step_cfg)
        # AOT-compiled paged chunk loops, keyed (chunk_len, speculative):
        # graceful degradation swaps in a shorter / non-speculative loop
        # under an emergency cap, each compiled once on first use
        self._loops: dict[tuple[int, bool], object] = {}
        self._prefills: dict[int, object] = {}   # bucket -> compiled prefill
        self._injects: dict[int, object] = {}    # bucket -> compiled inject
        self._suffix = None                  # AOT chunked-suffix prefill
        self._copy = None                    # AOT page-rows copy (CoW)
        self._pos = np.zeros((engine_cfg.n_slots,), np.int32)
        self._sample_key = jax.random.PRNGKey(engine_cfg.sample_seed)
        self._drafter = None
        self._dstate = None
        if engine_cfg.spec_k > 0:
            spec_block = (tfm.speculative_blockers(cfg)
                          or tfm.chunked_prefill_blockers(cfg))
            if spec_block:
                raise ValueError(f"{cfg.name}: speculative serving blocked "
                                 f"by {spec_block[0]}")
            self._drafter = get_drafter(engine_cfg.drafter, engine_cfg.spec_k,
                                        hist_len=engine_cfg.drafter_hist)
            # host mirror of the per-slot drafter state, synced like
            # pos/block_tables: seeded at prefill-on-join, carried through
            # the fused loop, read back at harvest
            self._dstate = self._drafter.init_state(engine_cfg.n_slots)
        # graceful-degradation state: level 0 = healthy, 1 = derate
        # (admission paused), 2 = emergency cap (+ shorter chunk, spec off)
        self._degrade_level = 0
        self._degrade_until = -1           # engine-clock step the window ends
        self._cap_frac = 1.0               # cap fraction in force (reporting)
        self._stall_until = -1             # heartbeat suppression window end
        self._eff_chunk = engine_cfg.decode_chunk

    # -- compiled pieces (AOT so compile time never lands in measured walls) -
    def _chunk_loop(self, chunk: int, spec: bool, *args):
        key = (chunk, spec)
        if key not in self._loops:
            if spec:
                fn = jax.jit(make_paged_speculative_decode_loop(
                    self.cfg, self.step_cfg, self.rules,
                    chunk, drafter=self._drafter,
                    greedy=self.ecfg.greedy,
                    temperature=self.ecfg.temperature), donate_argnums=(1,))
            else:
                fn = jax.jit(make_paged_decode_loop(
                    self.cfg, self.step_cfg, self.rules,
                    chunk, greedy=self.ecfg.greedy,
                    temperature=self.ecfg.temperature), donate_argnums=(1,))
            self._loops[key] = fn.lower(*args).compile()
        return self._loops[key]

    def _prefill(self, bucket: int):
        if bucket not in self._prefills:
            cfg, ctx = self.cfg, self._ctx

            def prefill(params, inputs):
                # full_cache: keep windowed layers linear so the bucket's
                # pad rows can't wrap over the real window tail before the
                # inject scatter reads it
                return tfm.prefill(params, inputs, cfg, ctx, max_len=bucket,
                                   full_cache=True)

            self._prefills[bucket] = jax.jit(prefill)
        return self._prefills[bucket]

    # -- host tier (two-tier KV hierarchy; docs/prefix_cache.md) -------------
    def _table_groups(self, cache) -> dict:
        """Pool groups whose axis 1 is the main page-id space: full-attention
        / MLA unit subs plus the stacked first-dense group.  Slot-indexed
        groups (windowed rings, SSM state, the hybrid shared buffer) are
        NOT pages and never appear here."""
        groups = {name: c for name, c in cache["units"].items()
                  if name in self._table_subs}
        if "dense" in cache:
            groups["dense"] = cache["dense"]
        return groups

    def _cache_page_bytes(self) -> int:
        """Device bytes of ONE page across every table-backed pool — scale
        pools included in int8 mode — the unit the transfer-energy model
        charges per page-out / page-in direction."""
        total = 0
        for c in self._table_groups(self.cache).values():
            for pool in c.values():                # (nu, P, ps, hkv, w)
                total += (pool.size // pool.shape[1]) * pool.dtype.itemsize
        return total

    def _fetch_page(self, page: int) -> dict:
        """D2H: copy one device page's rows out of every table-backed pool
        into host-memory numpy blobs (keys ``group/pool``)."""
        return {f"{name}/{key}": np.asarray(pool[:, page])
                for name, c in self._table_groups(self.cache).items()
                for key, pool in c.items()}

    def _restore_page(self, page: int, blob: dict) -> None:
        """H2D: scatter a fetched blob back into device page ``page``.
        One donated executable (page is a traced scalar) serves every
        promotion."""
        if self._tier_restore is None:
            tsubs = self._table_subs

            def restore(cache, page, blob):
                def put(name, c):
                    return {key: pool.at[:, page].set(
                        blob[f"{name}/{key}"].astype(pool.dtype))
                        for key, pool in c.items()}

                units = {name: put(name, c) if name in tsubs else c
                         for name, c in cache["units"].items()}
                out = {**cache, "units": units}
                if "dense" in cache:
                    out["dense"] = put("dense", cache["dense"])
                return out

            self._tier_restore = jax.jit(restore, donate_argnums=(0,))
        self.cache = self._tier_restore(
            self.cache, jnp.asarray(page, jnp.int32),
            {k: jnp.asarray(v) for k, v in blob.items()})

    def _sync_transfer(self) -> None:
        """Fold tier-transfer energy accrued in the paged-KV manager since
        the last sync into the run ledger.  Modelled, not measured: the
        manager charges bytes x J/byte as demotions/promotions happen; the
        engine surfaces the delta in ``energy_j`` (and breaks it out as
        ``transfer_j``) so J/token includes the cost of paging."""
        delta = self.kv.transfer_j - self._transfer_seen
        if delta > 0.0:
            self._transfer_seen = self.kv.transfer_j
            self._report.energy_j += delta
            self._report.transfer_j += delta

    def _inject(self, bucket: int):
        """Scatter a (padded) prefill cache into a slot's storage: one fused
        donated update across every pool group, keyed by per-group flat row
        ids from ``_inject_rows_tree`` (pad rows dropped).

        Per family: table-backed groups (k/v, MLA ``lat``, the stacked
        first-dense group) land on the slot's pages via
        ``PagedKVCache.inject_rows``; sliding-window groups scatter the
        prompt's last ``window`` rows into the slot's private ring pages;
        the hybrid shared buffer takes rows ``[0, L)`` of its per-slot
        linear span; SSM groups overwrite the slot's O(1) state slot
        outright (``slot`` is a traced scalar — one executable per bucket
        serves every slot).  Quantized pools ("k_scale" present) quantize
        the prefill rows on the way in — the same per-row int8 packing
        ``commit_spec_paged`` applies on the decode path, so cold-prefilled
        and decoded rows are indistinguishable."""
        if bucket not in self._injects:
            def inject(cache, pcache, rows, slot):
                def scatter(pool, vals, r):
                    nu = pool.shape[0]
                    flat = pool.reshape(nu, -1, *pool.shape[3:])
                    flat = flat.at[:, r].set(
                        vals.astype(flat.dtype), mode="drop")
                    return flat.reshape(pool.shape)

                def inject_group(c, src, r):
                    new = {}
                    for key in ("k", "v", "lat"):
                        if key not in c:
                            continue
                        vals = src[key][:, 0]  # (nu, bucket, ...)
                        if key + "_scale" in c:
                            vals, scales = quant.quantize_int8_rows(vals)
                            new[key + "_scale"] = scatter(
                                c[key + "_scale"], scales, r)
                        new[key] = scatter(c[key], vals, r)
                    return new

                units = {}
                for name, c in cache["units"].items():
                    src = pcache["units"][name]
                    if "conv" in c:       # SSM: overwrite the state slot
                        units[name] = {
                            "conv": c["conv"].at[:, slot].set(
                                src["conv"][:, 0].astype(c["conv"].dtype)),
                            "ssm": c["ssm"].at[:, slot].set(
                                src["ssm"][:, 0])}
                    else:
                        units[name] = inject_group(c, src, rows[name])
                out = {**cache, "units": units}
                if "shared" in cache:
                    out["shared"] = inject_group(
                        cache["shared"], pcache["shared"],
                        rows["__shared__"])
                if "dense" in cache:
                    # ring prefill keeps dense caches as a per-layer list
                    # (no unit axis); stack to the paged group's layout
                    src = {key: jnp.stack([c[key] for c in pcache["dense"]])
                           for key in cache["dense"]}
                    out["dense"] = inject_group(cache["dense"], src,
                                                rows["__dense__"])
                return out

            self._injects[bucket] = jax.jit(inject, donate_argnums=(0,))
        return self._injects[bucket]

    def _inject_rows_tree(self, slot: int, bucket: int, L: int) -> dict:
        """Per-group flat destination rows for ``_inject``: length-``bucket``
        arrays mapping prefill index ``p`` to a pool row, out-of-bounds
        (dropped) where ``p`` is padding or outside the group's retention.

        Table groups reuse ``PagedKVCache.inject_rows``; a window-``w``
        group keeps only ``[max(0, L - w), L)`` at ring offset ``p % Cw`` of
        the slot's private pages (older rows can never be attended again);
        the shared buffer is the slot's linear span."""
        cfg, kv = self.cfg, self.kv
        ps = kv.page_size
        main = np.asarray(kv.inject_rows(slot, bucket, L))
        rows = {}
        for i in range(tfm.unit_size(cfg)):
            name = f"sub{i}"
            if cfg.uses_ssm:
                continue                   # state slots need no row map
            w = 0 if cfg.use_mla else cfg.window_for_layer(i)
            if w <= 0:
                rows[name] = main
                continue
            nbw = -(-min(kv.max_blocks * ps, w) // ps)
            cw = nbw * ps
            p = np.arange(bucket)
            r = slot * cw + p % cw
            valid = (p >= max(0, L - w)) & (p < L)
            rows[name] = np.where(valid, r,
                                  self.ecfg.n_slots * cw).astype(np.int32)
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            cs = kv.max_blocks * ps
            p = np.arange(bucket)
            rows["__shared__"] = np.where(
                p < L, slot * cs + p,
                self.ecfg.n_slots * cs).astype(np.int32)
        if cfg.first_dense_layers:
            rows["__dense__"] = main
        return rows

    def _bucket(self, L: int) -> int:
        b = self.ecfg.min_prefill_bucket
        while b < L:
            b *= 2
        return b

    def _page_copy(self):
        """Copy-on-write: rows ``0..n_rows-1`` of ``src_page`` duplicated
        into ``dst_page`` across every unit pool (one fused donated
        update; src/dst/n_rows are traced scalars, ONE executable)."""
        if self._copy is None:
            ps = self.ecfg.page_size
            tsubs = self._table_subs

            def copy_group(c, src, dst, n_rows):
                i = jnp.arange(ps)
                new = {}
                for key, pool in c.items():   # k/v/lat (+ scales in int8)
                    nu, P = pool.shape[0], pool.shape[1]
                    flat = pool.reshape(nu, P * ps, *pool.shape[3:])
                    vals = flat[:, src * ps + i]
                    rows = jnp.where(i < n_rows, dst * ps + i, P * ps)
                    flat = flat.at[:, rows].set(vals, mode="drop")
                    new[key] = flat.reshape(pool.shape)
                return new

            def copy(cache, src, dst, n_rows):
                units = {name: (copy_group(c, src, dst, n_rows)
                                if name in tsubs else c)
                         for name, c in cache["units"].items()}
                out = {**cache, "units": units}
                if "dense" in cache:
                    out["dense"] = copy_group(cache["dense"], src, dst,
                                              n_rows)
                return out

            self._copy = jax.jit(copy, donate_argnums=(0,))
        return self._copy

    def _suffix_step(self, args):
        if self._suffix is None:
            fn = jax.jit(make_prefill_suffix_step(
                self.cfg, self.step_cfg, self.rules), donate_argnums=(1,))
            self._suffix = fn.lower(*args).compile()
        return self._suffix

    def _prefill_suffix(self, slot: int, req: Request, m: int):
        """Chunked paged prefill of the uncached suffix ``prompt[m:]``:
        fixed-shape verify sweeps against the slot's (partly shared)
        pages, committed rows advancing ``pos`` in place.  Returns the
        logits row scoring the token after the prompt's last token."""
        ecfg = self.ecfg
        L = req.prompt_len
        suffix = np.asarray(req.prompt[m:])
        qc = ecfg.prefill_chunk
        tok_shape = (ecfg.n_slots, qc) + suffix.shape[1:]
        logits, r = None, 0
        for c0 in range(0, L - m, qc):
            r = min(qc, L - m - c0)
            tok = np.zeros(tok_shape, np.int32)
            tok[slot, :r] = suffix[c0:c0 + r]
            ncommit = np.zeros((ecfg.n_slots,), np.int32)
            ncommit[slot] = r
            pos = self._pos.copy()
            pos[slot] = m + c0
            self.cache = {**self.cache, "pos": jnp.asarray(pos),
                          "block_tables": jnp.asarray(self.kv.tables)}
            args = (self.params, self.cache, jnp.asarray(tok),
                    jnp.asarray(ncommit))
            logits, self.cache = self._suffix_step(args)(*args)
        return logits[slot, r - 1]

    # -- join ----------------------------------------------------------------
    def _sample_first(self, logits_row, rid: int):
        """Sample the prefill's token (greedy or temperature) — position
        prompt_len - 1 of the padded prefill logits."""
        if self.ecfg.greedy:
            return np.asarray(jnp.argmax(logits_row, axis=-1), np.int32)
        key = jax.random.fold_in(self._sample_key, (rid << 1) | 1)
        nxt = jax.random.categorical(
            key, logits_row / self.ecfg.temperature, axis=-1)
        return np.asarray(nxt, np.int32)

    def _join(self, slot: int, req: Request, m: int, copy, t0: float) -> None:
        L = req.prompt_len
        if L + req.max_new_tokens > self.ecfg.max_len:
            raise ValueError(f"request {req.rid}: prompt {L} + "
                             f"{req.max_new_tokens} new > max_len "
                             f"{self.ecfg.max_len}")
        if copy is not None:
            # CoW: the match ended inside a shared page — duplicate the
            # matched rows into the slot's private page before the suffix
            # prefill writes right behind them
            self.cache = self._page_copy()(
                self.cache, jnp.asarray(copy.src_page, jnp.int32),
                jnp.asarray(copy.dst_page, jnp.int32),
                jnp.asarray(copy.n_rows, jnp.int32))
            self.kv.copy_done(copy.src_page)
        if m > 0:
            # prefill ONLY the uncached suffix, through the paged verify
            # sweep (chunked, fixed-shape, in-place commit)
            logits_row = self._prefill_suffix(slot, req, m)
        else:
            # cold prompt: classic bucketed prefill + page inject.  SSM
            # families prefill at the EXACT prompt length: attention caches
            # drop the bucket's pad rows at inject, but recurrent state is
            # a reduction over every fed token — pad tokens would poison
            # the state slots (costs one compile per distinct prompt
            # length instead of per bucket)
            bucket = L if self.cfg.uses_ssm else self._bucket(L)
            pad_shape = (1, bucket - L) + req.prompt.shape[1:]
            inputs = np.concatenate(
                [req.prompt[None], np.zeros(pad_shape, np.int32)], axis=1)
            logits, pcache = self._prefill(bucket)(self.params,
                                                   jnp.asarray(inputs))
            logits_row = logits[0, L - 1]
            rows = {k: jnp.asarray(v) for k, v in
                    self._inject_rows_tree(slot, bucket, L).items()}
            self.cache = self._inject(bucket)(self.cache, pcache, rows,
                                              jnp.asarray(slot, jnp.int32))
        first = self._sample_first(logits_row, req.rid)
        self._pos[slot] = L
        if self._use_prefix:
            # index the prompt's (now fully written) pages for future joins
            self.kv.register_prefix(slot, np.asarray(req.prompt))
        if self._drafter is not None:
            self._drafter.seed_request(self._dstate, slot, req.prompt, first)
        state = self.scheduler.slots[slot]
        state.next_token = first
        state.tok_start = len(self._results[req.rid].tokens)
        res = self._results[req.rid]
        res.slot = slot
        if res.admit_step < 0:        # requeued joins keep first-admit stats
            res.admit_step = self._now
            res.admit_t = time.perf_counter() - t0
        res.prefill_tokens_saved += m
        self._report.prompt_tokens += L
        self._report.prefill_tokens_saved += m
        if self.on_prefill is not None:
            energy = self.on_prefill(L - m, m)
            if energy:
                self._report.energy_j += energy
                res.energy_j += energy
        res.tokens.append(first.tolist() if first.ndim else int(first))
        if req.eos_id is not None and first.ndim == 0 \
                and int(first) == req.eos_id:
            state.remaining = 0
            res.finish_reason = "eos"
        if state.remaining <= 0:                  # max_new 1, or instant EOS
            res.finish_reason = res.finish_reason or "max_new_tokens"
            res.finish_step = self._now
            res.finish_t = time.perf_counter() - t0
            self.scheduler.finish(slot)
            self._pos[slot] = 0

    # -- preemption ----------------------------------------------------------
    def _preempt(self, slot: int, t0: float) -> None:
        """Evict ``slot`` on page pressure: re-queue its request with the
        tokens generated so far folded into the prompt (arrival = now),
        index its pages in the prefix cache (so the requeue mostly
        restores instead of recomputing), then free the slot."""
        state = self.scheduler.slots[slot]
        req = state.request
        res = self._results[req.rid]
        gen = np.asarray(res.tokens[state.tok_start:], np.int32)
        prompt = np.asarray(req.prompt, np.int32)
        if gen.size:
            prompt = np.concatenate([prompt, gen.reshape((-1,) +
                                                         prompt.shape[1:])])
        written = int(self._pos[slot])    # KV committed through written - 1
        if self._use_prefix:
            self.kv.register_prefix(slot, prompt[:written])
        new_req = dataclasses.replace(req, prompt=prompt,
                                      max_new_tokens=state.remaining,
                                      arrival_step=self._now)
        self.scheduler.finish(slot)
        self._pos[slot] = 0
        self._queue.push(new_req)
        res.n_preemptions += 1
        self._report.n_preemptions += 1

    def _grow_pages(self, t0: float, need: int | None = None) -> None:
        """Lazy-allocation mode: before a chunk, grow every active slot's
        pages to cover the chunk's writes, preempting the lowest-priority
        slot when the pool runs dry (``Scheduler.victim``: lowest
        priority, then most recently admitted)."""
        ecfg = self.ecfg
        if need is None:
            need = ecfg.decode_chunk * (ecfg.spec_k + 1)
        slots = self.scheduler.slots
        order = sorted(self.scheduler.active_slots(),
                       key=lambda s: (-slots[s].request.priority,
                                      slots[s].seq))
        for slot in order:
            if slots[slot] is None:
                continue                   # preempted earlier this pass
            # clamp the ask to the request's own context end: within-chunk
            # overrun past the budget writes scratch (contained), so pages
            # past ctx — or past the table width — are never needed
            req = slots[slot].request
            ctx = req.prompt_len + req.max_new_tokens - 1
            target = min(int(self._pos[slot]) + need, ctx, self.kv.max_len)
            while not self.kv.ensure(slot, target):
                victim = self.scheduler.victim()
                if victim == slot:
                    if self.scheduler.n_active <= 1:
                        raise RuntimeError(
                            f"request {slots[slot].request.rid}: page pool "
                            f"({self.kv.n_pages} pages) too small even at "
                            "zero concurrency; raise n_pages")
                    self._preempt(slot, t0)
                    break
                self._preempt(victim, t0)

    # -- harvest -------------------------------------------------------------
    def _harvest(self, toks: np.ndarray, t0: float) -> dict[int, int]:
        """Plain harvest — exactly the speculative harvest where every step
        yielded one token.  toks: (n_slots, chunk[, n_cb])."""
        counts = np.ones(toks.shape[:2], np.int32)
        return self._harvest_spec(toks[:, :, None], counts, t0)

    def _harvest_spec(self, toks: np.ndarray, counts: np.ndarray,
                      t0: float) -> dict[int, int]:
        """Append each active slot's kept tokens, finish on EOS / budget.

        Each step yields ``counts[slot, s]`` tokens (1 on the plain path;
        accepted drafts + the bonus token, 1..K+1, when speculating) —
        consumed in order at chunk granularity.  Returns kept (useful)
        token counts per request id for this chunk — the
        energy-attribution weights.  toks: (n_slots, steps, K+1[, n_cb])."""
        kept_by_rid: dict[int, int] = {}
        for slot in self.scheduler.active_slots():
            state = self.scheduler.slots[slot]
            req = state.request
            res = self._results[req.rid]
            kept = 0
            budget = state.remaining
            for s in range(toks.shape[1]):
                if res.finish_reason == "eos" or kept >= budget:
                    break
                for i in range(int(counts[slot, s])):
                    t = toks[slot, s, i]
                    res.tokens.append(t.tolist() if t.ndim else int(t))
                    kept += 1
                    if req.eos_id is not None and t.ndim == 0 \
                            and int(t) == req.eos_id:
                        res.finish_reason = "eos"
                        break
                    if kept >= budget:
                        break
            kept_by_rid[req.rid] = kept
            state.remaining = 0 if res.finish_reason == "eos" \
                else state.remaining - kept
            # the loop's carried token: last emitted token of the last step
            state.next_token = toks[slot, -1, max(int(counts[slot, -1]) - 1, 0)]
            if state.remaining == 0:
                res.finish_reason = res.finish_reason or "max_new_tokens"
                res.finish_step = self._now + self._eff_chunk
                res.finish_t = time.perf_counter() - t0
                self.scheduler.finish(slot)
                self._pos[slot] = 0
        return kept_by_rid

    # -- chaos + degradation -------------------------------------------------
    def degrade(self, level: int, *, steps: int, cap: float = 1.0) -> None:
        """Enter (or extend/deepen) a degradation window for ``steps``
        engine-clock steps.  Level 1 (derate) pauses admission; level 2
        (emergency cap) additionally halves the decode chunk and drops
        speculative K.  Called from fault injection and from the launcher
        when an ``EmergencyPower``/``NodeDerated`` event lands on the bus;
        the window clears itself when the clock passes its end."""
        self._degrade_level = max(self._degrade_level, int(level))
        self._degrade_until = max(self._degrade_until,
                                  self._now + max(int(steps), 1))
        if cap:
            self._cap_frac = min(self._cap_frac, float(cap))

    @property
    def degrade_level(self) -> int:
        return self._degrade_level

    def _apply_faults(self, t0: float) -> None:
        """Poll the injector on the decode-step clock and apply what came
        due.  ``engine_crash`` raises ``EngineCrash`` (the caller restores
        from the last snapshot); everything else is absorbed in place."""
        if self.injector is None:
            return
        for ev in self.injector.poll(self._now):
            self._report.n_faults_injected += 1
            if ev.kind == "engine_crash":
                raise EngineCrash(self._now)
            if ev.kind == "slot_crash":
                slot = int(ev.arg) % self.ecfg.n_slots
                if self.scheduler.slots[slot] is not None:
                    self._preempt(slot, t0)
                    self._report.requeued_requests += 1
            elif ev.kind == "page_corrupt":
                if corrupt_paged_kv(self.kv, self.injector.rng) is not None:
                    # audit + repair immediately: nothing may allocate on
                    # corrupted metadata
                    self.kv.verify_invariants(repair=True)
                    self._report.n_pages_quarantined = \
                        len(self.kv.quarantined)
            elif ev.kind == "stall":
                self._stall_until = self._now + \
                    max(ev.duration, self.ecfg.decode_chunk)
            elif ev.kind == "derate":
                self.degrade(1, steps=max(ev.duration, 1), cap=ev.arg)
            elif ev.kind == "emergency_cap":
                self.degrade(2, steps=max(ev.duration, 1), cap=ev.arg)
            elif self.on_fault is not None:
                self.on_fault(ev)     # bus_drop / bus_delay: launcher-owned

    # -- snapshot / restore --------------------------------------------------
    @staticmethod
    def _ser_req(req: Request) -> dict:
        p = np.asarray(req.prompt)
        return {"rid": req.rid, "prompt": p.tolist(), "dtype": str(p.dtype),
                "max_new_tokens": req.max_new_tokens,
                "arrival_step": req.arrival_step, "eos_id": req.eos_id,
                "priority": req.priority}

    @staticmethod
    def _de_req(rec: dict) -> Request:
        return Request(rid=int(rec["rid"]),
                       prompt=np.asarray(rec["prompt"], dtype=rec["dtype"]),
                       max_new_tokens=int(rec["max_new_tokens"]),
                       arrival_step=int(rec["arrival_step"]),
                       eos_id=rec["eos_id"], priority=int(rec["priority"]))

    def snapshot(self) -> dict:
        """Recoverable engine state as a checkpointable pytree: the device
        KV pools plus a JSON blob (uint8 leaf) holding the request queue,
        per-slot progress, results so far, report counters, and the
        paged-KV host metadata (block tables + trie).  Taken at chunk
        boundaries only, so every slot's KV is committed through its
        ``pos`` and the fold-into-prompt replay is exact."""
        slots = []
        for slot in self.scheduler.active_slots():
            state = self.scheduler.slots[slot]
            slots.append({"slot": slot,
                          "request": self._ser_req(state.request),
                          "remaining": int(state.remaining),
                          "tok_start": int(state.tok_start),
                          "written": int(self._pos[slot])})
        rep = {f.name: getattr(self._report, f.name)
               for f in dataclasses.fields(self._report)
               if f.name != "results"}
        meta = {"now": self._now, "chunk_idx": self._chunk_idx,
                "occ_sum": self._occ_sum,
                # an emergency-cap/derate window outlives the process that
                # crashed under it — the restored engine must stay degraded
                # until the window actually ends
                "degrade": {"level": self._degrade_level,
                            "until": self._degrade_until,
                            "cap": self._cap_frac,
                            "stall": self._stall_until},
                "req_order": self._req_order,
                "queue": [self._ser_req(r) for r in self._queue.pending()],
                "slots": slots,
                "results": {str(rid): dataclasses.asdict(res)
                            for rid, res in self._results.items()},
                "report": rep,
                "kv": self.kv.state_dict()}
        blob = np.frombuffer(json.dumps(meta).encode(), np.uint8).copy()
        return {"cache": self.cache, "meta": blob}

    def save_snapshot(self) -> None:
        if self._ckpt is None:
            raise ValueError("engine was built without snapshot_dir")
        self._ckpt.save(self.snapshot(), self._now)

    @classmethod
    def restore(cls, cfg, engine_cfg: EngineConfig, params, snapshot_dir,
                *, step: int | None = None, **kwargs) -> "ServeEngine":
        """Rebuild an engine from the latest committed snapshot.

        The device pools and paged-KV metadata (incl. the prefix trie) come
        back verbatim — then every in-flight slot is converted into a
        requeued request with its generated tokens folded into the prompt
        (PR 5's preemption fold), so ``resume()`` re-admits it through
        ``admit_with_prefix`` against the restored trie: re-prefill is
        cheap and restored greedy streams are bit-identical to an
        uninterrupted run.  ``verify_invariants(repair=True)`` audits the
        restored pool, quarantining anything a crash corrupted."""
        eng = cls(cfg, engine_cfg, params, snapshot_dir=snapshot_dir,
                  **kwargs)
        like = {"cache": eng.cache, "meta": np.zeros((0,), np.uint8)}
        tree = restore_pytree(like, snapshot_dir, step)
        meta = json.loads(bytes(np.asarray(tree["meta"])))
        eng.cache = tree["cache"]
        eng.kv.load_state(meta["kv"])
        eng.kv.verify_invariants(repair=True)
        # transfer energy accrued before the crash is already inside the
        # restored report — only charge what happens from here on
        eng._transfer_seen = eng.kv.transfer_j
        eng._results = {int(rid): RequestResult(**rec)
                        for rid, rec in meta["results"].items()}
        eng._req_order = [int(r) for r in meta["req_order"]]
        eng._now = int(meta["now"])
        eng._chunk_idx = int(meta["chunk_idx"])
        eng._occ_sum = float(meta["occ_sum"])
        deg = meta["degrade"]
        eng._degrade_level = int(deg["level"])
        eng._degrade_until = int(deg["until"])
        eng._cap_frac = float(deg["cap"])
        eng._stall_until = int(deg["stall"])
        eng._report = EngineReport(results=[], **meta["report"])
        eng._report.n_restores += 1
        if eng.injector is not None:
            # the crash's own injection died with the process (snapshots
            # predate it) — the injector's log is authoritative
            eng._report.n_faults_injected = max(
                eng._report.n_faults_injected, eng.injector.n_injected)
            # derate/cap windows are EXTERNAL conditions: one that fired
            # after the last snapshot is one-shot (won't replay) but its
            # window may still be open — re-impose the remainder
            for ev in eng.injector.log:
                lvl = {"derate": 1, "emergency_cap": 2}.get(ev.kind)
                if lvl and ev.step + ev.duration > eng._now:
                    eng.degrade(lvl, steps=ev.step + ev.duration - eng._now,
                                cap=ev.arg)
        eng._report.n_pages_quarantined = len(eng.kv.quarantined)
        reqs = [eng._de_req(rec) for rec in meta["queue"]]
        for srec in meta["slots"]:
            req = eng._de_req(srec["request"])
            res = eng._results[req.rid]
            gen = np.asarray(res.tokens[int(srec["tok_start"]):], np.int32)
            prompt = np.asarray(req.prompt, np.int32)
            if gen.size:
                prompt = np.concatenate(
                    [prompt, gen.reshape((-1,) + prompt.shape[1:])])
            slot = int(srec["slot"])
            if eng._use_prefix and slot in eng.kv.allocated:
                # index the dead slot's written pages before releasing them
                # — the requeue then restores from the trie, not compute
                eng.kv.register_prefix(slot, prompt[:int(srec["written"])])
            reqs.append(dataclasses.replace(
                req, prompt=prompt, max_new_tokens=int(srec["remaining"]),
                arrival_step=eng._now))
            eng._report.requeued_requests += 1
        for slot in list(eng.kv.allocated):   # slots died with the process
            eng.kv.release(slot)
        eng._pos[:] = 0
        eng._queue = RequestQueue(reqs)
        return eng

    def resume(self) -> EngineReport:
        """Continue a restored engine to completion."""
        return self._drive()

    # -- main loop -----------------------------------------------------------
    def _begin(self, requests: list[Request]) -> None:
        self._queue = RequestQueue(requests)
        self._results = {r.rid: RequestResult(
            rid=r.rid, prompt_len=r.prompt_len, arrival_step=r.arrival_step,
            max_new_tokens=r.max_new_tokens) for r in requests}
        self._req_order = [r.rid for r in requests]
        self._now = 0
        self._chunk_idx = 0
        self._occ_sum = 0.0
        self._report = EngineReport(results=[], spec_k=self.ecfg.spec_k)
        self._transfer_seen = self.kv.transfer_j
        self._degrade_level = 0
        self._degrade_until = -1
        self._cap_frac = 1.0
        self._stall_until = -1
        self._eff_chunk = self.ecfg.decode_chunk

    def run(self, requests: list[Request]) -> EngineReport:
        self._begin(requests)
        if self._ckpt is not None and self.snapshot_every > 0:
            # step-0 snapshot: a crash BEFORE the first periodic save must
            # still restore (to the full queue), never lose the run
            self.save_snapshot()
        return self._drive()

    def _drive(self) -> EngineReport:
        ecfg = self.ecfg
        queue = self._queue
        report = self._report
        t0 = time.perf_counter()
        n_cb = self.cfg.n_codebooks
        tok_shape = (ecfg.n_slots, 1) + ((n_cb,) if n_cb else ())
        tok_in = np.zeros(tok_shape, np.int32)

        while len(queue) or self.scheduler.n_active:
            self._apply_faults(t0)           # may raise EngineCrash
            if self._degrade_level and self._now >= self._degrade_until:
                self._degrade_level = 0      # window cleared: full service
                self._cap_frac = 1.0
            degraded = self._degrade_level
            t_p = time.perf_counter()
            if not degraded:                 # degraded: admission paused
                for slot, req, m, copy in self.scheduler.poll(queue,
                                                              self._now):
                    self._join(slot, req, m, copy, t0)
            # emergency cap: halve the decode chunk, drop speculation — the
            # chunk's compute shrinks instead of violating the cap
            eff_chunk = ecfg.decode_chunk if degraded < 2 \
                else max(ecfg.decode_chunk // 2, 1)
            spec = self._drafter is not None and degraded < 2
            eff_k = ecfg.spec_k if spec else 0
            self._eff_chunk = eff_chunk
            if ecfg.preempt:
                # grows/preempts but always leaves >= 1 slot active (the
                # last survivor raises rather than self-preempting)
                self._grow_pages(t0, eff_chunk * (eff_k + 1))
            # joins may have paged prefixes back in, growth may have paged
            # cold pages out — fold the modelled transfer energy in now so
            # every ChunkStats-adjacent ledger read sees it
            self._sync_transfer()
            report.prefill_wall_s += time.perf_counter() - t_p

            if self.scheduler.n_active == 0:
                if degraded:
                    # admission is paused and nothing is running: jump the
                    # clock to the window's end instead of spinning (or
                    # tripping the inadmissible-at-zero-load check below)
                    self._now = max(self._degrade_until, self._now + 1)
                    report.degraded_steps += eff_chunk
                    continue
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                if nxt <= self._now:
                    # the head request is due but poll refused it on an EMPTY
                    # engine: pages/admission can never be satisfied — fail
                    # loudly instead of spinning the idle branch forever
                    raise RuntimeError(
                        f"request {queue.peek_ready(self._now).rid} not "
                        f"admissible at zero load (pool {self.kv.n_pages} "
                        "pages / admission hook); raise n_pages or the "
                        "power budget")
                self._now = nxt                   # idle: jump to next arrival
                continue

            active = np.zeros((ecfg.n_slots,), np.int32)
            for slot in self.scheduler.active_slots():
                active[slot] = 1
                tok_in[slot, 0] = self.scheduler.slots[slot].next_token
            # sync host mirrors (membership may have changed since last chunk)
            self.cache = {**self.cache,
                          "pos": jnp.asarray(self._pos),
                          "block_tables": jnp.asarray(self.kv.tables)}
            args = [self.params, self.cache, jnp.asarray(tok_in),
                    jnp.asarray(active)]
            if spec:
                args.append({k: jnp.asarray(v)
                             for k, v in self._dstate.items()})
            if not ecfg.greedy:
                # even namespace: first-token keys live at (rid << 1) | 1
                args.append(jax.random.fold_in(self._sample_key,
                                               self._chunk_idx << 1))
            loop = self._chunk_loop(eff_chunk, spec, *args)
            t_c = time.perf_counter()
            if spec:
                toks, counts, self.cache, dstate = loop(*args)
                toks = np.asarray(jax.block_until_ready(toks))
                counts = np.asarray(counts)
                # np.array (not asarray): seed_row mutates this mirror on join
                self._dstate = {k: np.array(v) for k, v in dstate.items()}
            else:
                toks, self.cache = loop(*args)
                toks = np.asarray(jax.block_until_ready(toks))
            wall = time.perf_counter() - t_c

            n_active = int(active.sum())
            if spec:
                # device pos advanced by this chunk's per-slot emitted counts
                self._pos += counts.sum(axis=1).astype(np.int32)
                kept_by_rid = self._harvest_spec(toks, counts, t0)
                kept = sum(kept_by_rid.values())
                computed = n_active * eff_chunk * (eff_k + 1)
                proposed = n_active * eff_chunk * eff_k
                accepted = int(counts.sum()) - n_active * eff_chunk
            else:
                self._pos[active.astype(bool)] += eff_chunk
                kept_by_rid = self._harvest(toks, t0)
                kept = sum(kept_by_rid.values())
                computed = n_active * eff_chunk
                proposed = accepted = 0
            self._now += eff_chunk
            self._chunk_idx += 1
            if degraded:
                report.degraded_steps += eff_chunk

            stats = ChunkStats(step=self._chunk_idx, wall_s=wall,
                               n_slots=ecfg.n_slots, n_active=n_active,
                               tokens_kept=kept, tokens_computed=computed,
                               drafts_proposed=proposed,
                               drafts_accepted=accepted,
                               clock_step=self._now,
                               degrade_level=degraded)
            energy = self.on_chunk(stats) if self.on_chunk is not None else None
            report.n_chunks += 1
            report.decode_wall_s += wall
            report.tokens_kept += kept
            report.tokens_computed += stats.tokens_computed
            report.drafts_proposed += proposed
            report.drafts_accepted += accepted
            self._occ_sum += n_active / ecfg.n_slots
            if energy:
                report.energy_j += energy
                # charge occupied slots only, pro rata by kept tokens
                for rid, n in kept_by_rid.items():
                    if n > 0:
                        self._results[rid].energy_j += energy * n / max(kept, 1)
            if self.on_heartbeat is not None and self._now > self._stall_until:
                self.on_heartbeat(self._now, wall)
            if self._ckpt is not None and self.snapshot_every > 0 \
                    and self._chunk_idx % self.snapshot_every == 0:
                self.save_snapshot()

        # final poll: a fault due between the last chunk and run exit must
        # still fire (an engine_crash here restores + replays the tail —
        # results are only authoritative once this returns)
        self._apply_faults(t0)
        self._sync_transfer()
        report.n_demotions = self.kv.n_demotions
        report.n_promotions = self.kv.n_promotions
        report.occupancy = self._occ_sum / max(report.n_chunks, 1)
        report.results = [self._results[rid] for rid in self._req_order]
        return report
