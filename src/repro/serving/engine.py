"""Continuous-batching serving engine over the paged KV cache.

Converts the one-shot batch serving path into a stateful multi-request
loop: ragged requests join fixed decode slots mid-stream (prefill-on-join),
decode runs in fused chunks of ``decode_chunk`` tokens over ALL slots with
a per-slot validity mask (one AOT executable for every occupancy pattern),
and slots free on EOS / token budget at harvest, at chunk granularity.

Anatomy of one engine cycle::

    poll ──> prefill-on-join ──> sync tables/pos ──> fused chunk ──> harvest
     ^   (bucketed prompt,        (host mirrors       (paged loop,     │
     │    pages injected)          -> device)          donated cache)  │
     └──────────────────── free slots / pages on finish ───────────────┘

Telemetry: the engine itself is control-plane-agnostic — the launcher
passes an ``on_chunk`` hook that receives per-chunk :class:`ChunkStats`
(measured wall time, occupancy, useful-vs-computed tokens) and returns the
chunk's energy in joules (or ``None``).  Energy is attributed to requests
in proportion to their *kept* tokens, so J/token charges only occupied
slots — utilisation-honest under partial occupancy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.runtime.steps import (StepConfig, make_paged_decode_loop,
                                 make_run_ctx)
from repro.serving.paged_kv import PagedKVCache
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import RequestQueue, Scheduler


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs orthogonal to the model config."""
    n_slots: int = 4
    page_size: int = 16
    max_len: int = 256            # per-request prompt + generation ceiling
    decode_chunk: int = 8
    n_pages: int | None = None    # None: fully provisioned (no page waits)
    greedy: bool = True
    temperature: float = 1.0
    sample_seed: int = 0
    cache_dtype: str = "bfloat16"
    min_prefill_bucket: int = 8   # prompts pad up to pow2 buckets >= this


@dataclasses.dataclass(frozen=True)
class ChunkStats:
    """One fused chunk's telemetry, handed to the ``on_chunk`` hook."""
    step: int                     # chunk index
    wall_s: float                 # measured execution time (compile excluded)
    n_slots: int
    n_active: int                 # slots holding a live request
    tokens_kept: int              # useful tokens harvested this chunk
    tokens_computed: int          # n_active * chunk (incl. overrun)


@dataclasses.dataclass
class EngineReport:
    """Run summary + per-request results."""
    results: list[RequestResult]
    n_chunks: int = 0
    decode_wall_s: float = 0.0
    prefill_wall_s: float = 0.0
    tokens_kept: int = 0
    tokens_computed: int = 0
    energy_j: float = 0.0
    occupancy: float = 0.0        # mean active/slots over chunks

    @property
    def tok_per_s(self) -> float:
        return self.tokens_kept / max(self.decode_wall_s, 1e-9)

    @property
    def j_per_token(self) -> float:
        """Charges only tokens actually served — under partial occupancy
        this is the honest (higher) figure."""
        return self.energy_j / max(self.tokens_kept, 1)

    def latency_percentiles(self, qs=(50, 95)) -> dict[int, float]:
        lats = [r.latency_steps for r in self.results if r.finish_step >= 0]
        if not lats:
            return {q: float("nan") for q in qs}
        return {q: float(np.percentile(lats, q)) for q in qs}


class EnergyAwareAdmission:
    """Admission hook: admit while the predicted board draw at the
    *resulting* occupancy — under the cap currently in force — stays within
    a power budget.  Under a deep cap decode is memory-bound and occupancy
    is near-free, so the hook admits aggressively; at high caps it backs
    off, which is exactly the paper's serving trade expressed as admission
    control."""

    def __init__(self, device, workload_fn: Callable[[int], object],
                 budget_w: float, backend=None):
        self.device = device
        self.workload_fn = workload_fn        # n_active -> WorkloadProfile
        self.budget_w = float(budget_w)
        self.backend = backend                # CapBackend (current_cap())

    def __call__(self, request: Request, n_active_after: int) -> bool:
        cap = self.backend.current_cap() if self.backend is not None else 1.0
        est = self.device.estimate(self.workload_fn(n_active_after), cap)
        return est.power_w <= self.budget_w


class ServeEngine:
    """Drives the fused paged decode loop over live slots."""

    def __init__(self, cfg, engine_cfg: EngineConfig, params, *,
                 step_cfg: StepConfig | None = None, rules=None,
                 on_chunk: Callable[[ChunkStats], float | None] | None = None,
                 admission=None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.params = params
        self.step_cfg = step_cfg or StepConfig(remat="none")
        self.rules = rules
        self.on_chunk = on_chunk
        self.kv = PagedKVCache(cfg, n_slots=engine_cfg.n_slots,
                               page_size=engine_cfg.page_size,
                               max_len=engine_cfg.max_len,
                               n_pages=engine_cfg.n_pages,
                               dtype=engine_cfg.cache_dtype)
        self.scheduler = Scheduler(engine_cfg.n_slots, self.kv,
                                   admission=admission)
        self.cache = self.kv.make_cache()
        self._ctx = make_run_ctx(cfg, rules, self.step_cfg)
        self._loop = None                    # AOT-compiled paged chunk loop
        self._prefills: dict[int, object] = {}   # bucket -> compiled prefill
        self._injects: dict[int, object] = {}    # bucket -> compiled inject
        self._pos = np.zeros((engine_cfg.n_slots,), np.int32)
        self._sample_key = jax.random.PRNGKey(engine_cfg.sample_seed)

    # -- compiled pieces (AOT so compile time never lands in measured walls) -
    def _chunk_loop(self, *args):
        if self._loop is None:
            fn = jax.jit(make_paged_decode_loop(
                self.cfg, self.step_cfg, self.rules, self.ecfg.decode_chunk,
                greedy=self.ecfg.greedy, temperature=self.ecfg.temperature),
                donate_argnums=(1,))
            self._loop = fn.lower(*args).compile()
        return self._loop

    def _prefill(self, bucket: int):
        if bucket not in self._prefills:
            cfg, ctx = self.cfg, self._ctx

            def prefill(params, inputs):
                return tfm.prefill(params, inputs, cfg, ctx, max_len=bucket)

            self._prefills[bucket] = jax.jit(prefill)
        return self._prefills[bucket]

    def _inject(self, bucket: int):
        """Scatter a (padded) prefill cache into a slot's pages: one fused
        donated update across every unit pool, keyed by flat row ids from
        ``PagedKVCache.inject_rows`` (pad rows dropped)."""
        if bucket not in self._injects:
            def inject(cache, prefill_units, rows):
                units = {}
                for name, c in cache["units"].items():
                    src, new = prefill_units[name], {}
                    for key in ("k", "v"):
                        pool = c[key]                # (nu, P, ps, hkv, hd)
                        nu = pool.shape[0]
                        flat = pool.reshape(nu, -1, *pool.shape[3:])
                        flat = flat.at[:, rows].set(
                            src[key][:, 0].astype(flat.dtype), mode="drop")
                        new[key] = flat.reshape(pool.shape)
                    units[name] = new
                return {**cache, "units": units}

            self._injects[bucket] = jax.jit(inject, donate_argnums=(0,))
        return self._injects[bucket]

    def _bucket(self, L: int) -> int:
        b = self.ecfg.min_prefill_bucket
        while b < L:
            b *= 2
        return b

    # -- join ----------------------------------------------------------------
    def _sample_first(self, logits_row, rid: int):
        """Sample the prefill's token (greedy or temperature) — position
        prompt_len - 1 of the padded prefill logits."""
        if self.ecfg.greedy:
            return np.asarray(jnp.argmax(logits_row, axis=-1), np.int32)
        key = jax.random.fold_in(self._sample_key, (rid << 1) | 1)
        nxt = jax.random.categorical(
            key, logits_row / self.ecfg.temperature, axis=-1)
        return np.asarray(nxt, np.int32)

    def _join(self, slot: int, req: Request, t0: float) -> None:
        L = req.prompt_len
        if L + req.max_new_tokens > self.ecfg.max_len:
            raise ValueError(f"request {req.rid}: prompt {L} + "
                             f"{req.max_new_tokens} new > max_len "
                             f"{self.ecfg.max_len}")
        bucket = self._bucket(L)
        pad_shape = (1, bucket - L) + req.prompt.shape[1:]
        inputs = np.concatenate(
            [req.prompt[None], np.zeros(pad_shape, np.int32)], axis=1)
        logits, pcache = self._prefill(bucket)(self.params,
                                               jnp.asarray(inputs))
        first = self._sample_first(logits[0, L - 1], req.rid)
        rows = jnp.asarray(self.kv.inject_rows(slot, bucket, L))
        self.cache = self._inject(bucket)(self.cache, pcache["units"], rows)
        self._pos[slot] = L
        state = self.scheduler.slots[slot]
        state.next_token = first
        res = self._results[req.rid]
        res.slot = slot
        res.admit_step = self._now
        res.admit_t = time.perf_counter() - t0
        res.tokens.append(first.tolist() if first.ndim else int(first))
        if req.eos_id is not None and first.ndim == 0 \
                and int(first) == req.eos_id:
            state.remaining = 0
            res.finish_reason = "eos"
        if state.remaining <= 0:                  # max_new 1, or instant EOS
            res.finish_reason = res.finish_reason or "max_new_tokens"
            res.finish_step = self._now
            res.finish_t = time.perf_counter() - t0
            self.scheduler.finish(slot)
            self._pos[slot] = 0

    # -- harvest -------------------------------------------------------------
    def _harvest(self, toks: np.ndarray, t0: float) -> dict[int, int]:
        """Append each active slot's kept tokens, finish on EOS / budget.
        Returns kept (useful) token counts per request id for this chunk —
        the energy-attribution weights."""
        kept_by_rid: dict[int, int] = {}
        for slot in self.scheduler.active_slots():
            state = self.scheduler.slots[slot]
            req = state.request
            res = self._results[req.rid]
            kept = 0
            for i in range(min(state.remaining, toks.shape[1])):
                t = toks[slot, i]
                res.tokens.append(t.tolist() if t.ndim else int(t))
                kept += 1
                if req.eos_id is not None and t.ndim == 0 \
                        and int(t) == req.eos_id:
                    res.finish_reason = "eos"
                    break
            kept_by_rid[req.rid] = kept
            state.remaining = 0 if res.finish_reason == "eos" \
                else state.remaining - kept
            state.next_token = toks[slot, -1]     # feeds the next chunk
            if state.remaining == 0:
                res.finish_reason = res.finish_reason or "max_new_tokens"
                res.finish_step = self._now + self.ecfg.decode_chunk
                res.finish_t = time.perf_counter() - t0
                self.scheduler.finish(slot)
                self._pos[slot] = 0
        return kept_by_rid

    # -- main loop -----------------------------------------------------------
    def run(self, requests: list[Request]) -> EngineReport:
        ecfg = self.ecfg
        queue = RequestQueue(requests)
        self._results = {r.rid: RequestResult(
            rid=r.rid, prompt_len=r.prompt_len, arrival_step=r.arrival_step,
            max_new_tokens=r.max_new_tokens) for r in requests}
        self._now = 0
        report = EngineReport(results=[])
        occ_sum = 0.0
        t0 = time.perf_counter()
        n_cb = self.cfg.n_codebooks
        tok_shape = (ecfg.n_slots, 1) + ((n_cb,) if n_cb else ())
        tok_in = np.zeros(tok_shape, np.int32)
        chunk_idx = 0

        while len(queue) or self.scheduler.n_active:
            t_p = time.perf_counter()
            for slot, req in self.scheduler.poll(queue, self._now):
                self._join(slot, req, t0)
            report.prefill_wall_s += time.perf_counter() - t_p

            if self.scheduler.n_active == 0:
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                if nxt <= self._now:
                    # the head request is due but poll refused it on an EMPTY
                    # engine: pages/admission can never be satisfied — fail
                    # loudly instead of spinning the idle branch forever
                    raise RuntimeError(
                        f"request {queue.peek_ready(self._now).rid} not "
                        f"admissible at zero load (pool {self.kv.n_pages} "
                        "pages / admission hook); raise n_pages or the "
                        "power budget")
                self._now = nxt                   # idle: jump to next arrival
                continue

            active = np.zeros((ecfg.n_slots,), np.int32)
            for slot in self.scheduler.active_slots():
                active[slot] = 1
                tok_in[slot, 0] = self.scheduler.slots[slot].next_token
            # sync host mirrors (membership may have changed since last chunk)
            self.cache = {**self.cache,
                          "pos": jnp.asarray(self._pos),
                          "block_tables": jnp.asarray(self.kv.tables)}
            args = [self.params, self.cache, jnp.asarray(tok_in),
                    jnp.asarray(active)]
            if not ecfg.greedy:
                # even namespace: first-token keys live at (rid << 1) | 1
                args.append(jax.random.fold_in(self._sample_key,
                                               chunk_idx << 1))
            loop = self._chunk_loop(*args)
            t_c = time.perf_counter()
            toks, self.cache = loop(*args)
            toks = np.asarray(jax.block_until_ready(toks))
            wall = time.perf_counter() - t_c

            n_active = int(active.sum())
            self._pos[active.astype(bool)] += ecfg.decode_chunk
            kept_by_rid = self._harvest(toks, t0)
            kept = sum(kept_by_rid.values())
            self._now += ecfg.decode_chunk
            chunk_idx += 1

            stats = ChunkStats(step=chunk_idx, wall_s=wall,
                               n_slots=ecfg.n_slots, n_active=n_active,
                               tokens_kept=kept,
                               tokens_computed=n_active * ecfg.decode_chunk)
            energy = self.on_chunk(stats) if self.on_chunk is not None else None
            report.n_chunks += 1
            report.decode_wall_s += wall
            report.tokens_kept += kept
            report.tokens_computed += stats.tokens_computed
            occ_sum += n_active / ecfg.n_slots
            if energy:
                report.energy_j += energy
                # charge occupied slots only, pro rata by kept tokens
                for rid, n in kept_by_rid.items():
                    if n > 0:
                        self._results[rid].energy_j += energy * n / max(kept, 1)

        report.occupancy = occ_sum / max(report.n_chunks, 1)
        report.results = [self._results[r.rid] for r in requests]
        return report
