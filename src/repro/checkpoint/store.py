"""Sharded, atomic, resumable checkpoints.

Layout:   <dir>/step_<n>/
              manifest.json          tree structure + shapes/dtypes
              arrays.npz             leaf data (path-keyed)
              _COMMITTED             atomicity marker (written LAST)

Properties the FT supervisor relies on:
  * atomic: a crash mid-save leaves no _COMMITTED marker; restore ignores
    uncommitted steps (write-to-temp + rename is used for every file),
  * resumable: ``latest_step`` finds the newest committed step,
  * reshardable: arrays are saved UNSHARDED (gathered); restore places them
    under whatever NamedShardings the *new* mesh's rules produce — this is
    what makes elastic re-mesh (drop a DP rank) a plain restore,
  * async-friendly: ``CheckpointManager(save_async=True)`` hands the
    gathered host arrays to a writer thread so the train loop resumes
    immediately (the gather is the only on-path cost).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_pytree(tree: Any, directory: str | os.PathLike, step: int) -> pathlib.Path:
    """Atomic save of one pytree as step_<step>."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=str(directory)))
    try:
        flat = _flatten_with_paths(tree)
        manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in flat.items()}
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "_COMMITTED").write_text(str(step))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / "_COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_pytree(like: Any, directory: str | os.PathLike,
                   step: int | None = None, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally place each leaf
    under ``shardings`` (same tree structure) — the elastic-remesh path."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    src = directory / f"step_{step:08d}"
    if not (src / "_COMMITTED").exists():
        raise FileNotFoundError(f"step {step} is not committed")
    data = np.load(src / "arrays.npz")

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(paths))
    leaves = []
    for (path, leaf), sh in zip(paths, sh_leaves):
        key = _SEP.join(_path_str(p) for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            if arr.dtype.kind == "V" and \
                    np.dtype(leaf.dtype).itemsize == arr.dtype.itemsize:
                # extended dtypes (bfloat16 / fp8) survive np.savez only as
                # raw void bytes — bit-reinterpret, never value-cast
                arr = arr.view(leaf.dtype)
            else:
                arr = arr.astype(leaf.dtype)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Keeps the last ``keep`` committed steps; optional async writer."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 save_async: bool = False):
        self.directory = pathlib.Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.save_async = save_async
        self._pending: threading.Thread | None = None

    def save(self, tree: Any, step: int):
        host_tree = jax.tree.map(np.asarray, tree)   # gather once, on-path
        if self.save_async:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(host_tree, step), daemon=True)
            self._pending.start()
        else:
            self._write(host_tree, step)

    def _write(self, host_tree, step):
        save_pytree(host_tree, self.directory, step)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, like: Any, step: int | None = None, shardings: Any = None):
        self.wait()
        return restore_pytree(like, self.directory, step, shardings)

    def latest_step(self) -> int | None:
        self.wait()
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.directory.iterdir()
            if d.name.startswith("step_") and (d / "_COMMITTED").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
