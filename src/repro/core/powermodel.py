"""Device power/performance models — the hardware-adaptation layer.

The paper enforces caps with ``nvidia-smi -pl`` and reads power from NVML
MSRs.  This container has neither GPUs nor TPUs, so FROST's *mechanism*
(profile caps -> fit -> minimise) runs against a calibrated analytic device
model instead.  The model is physics-first, not outcome-fitted:

  * clock governor: dynamic power ~ C V^2 f with V ~ f  =>  P_dyn ~ f^3.
    Under cap x the governor picks the largest normalised clock f_hat <= 1
    such that   P_static + u * (P_tdp - P_static) * f_hat^3  <=  x * P_tdp
    (u = the workload's compute duty cycle; a starved GPU never hits its cap
    — this is what makes LeNet the paper's flat outlier).
  * runtime: the step is split into roofline terms.  Only the compute-bound
    seconds stretch when the core clock drops:
        T(x) = blend( t_c / f_hat(x),  t_m,  t_x ) + t_host
    matching the paper's observation that capping is nearly free while the
    program is partially memory-bound and blows up once compute-bound.
  * instability floor: the paper reports circuit instability below ~30%
    caps; the governor refuses caps below ``spec.min_cap``.

The same split (t_c, t_m, t_x) is exactly what the multi-pod dry-run's
roofline analysis produces, so FROST's recommendations for the LM archs are
driven by the compiled artifact, not hand-waving.
"""
from __future__ import annotations

import dataclasses

import numpy as np


# --------------------------------------------------------------------------
# Device catalogue
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Static description of one accelerator board/chip."""
    name: str
    tdp_w: float                  # board power at 100% cap
    static_w: float               # non-scalable (idle) power
    peak_flops: float             # peak FLOP/s in the training dtype
    hbm_bw: float                 # HBM bytes/s
    link_bw: float                # interconnect bytes/s per link
    min_cap: float = 0.30         # instability floor (paper Sec IV-C)
    min_clock: float = 0.25       # normalised clock floor
    matmul_efficiency: float = 0.85   # achievable fraction of peak on MXU/tensor cores
    vmem_bytes: int = 0
    hbm_bytes: int = 0

    def cap_watts(self, cap: float) -> float:
        return cap * self.tdp_w


# Paper setup no.1 / no.2 GPUs (desktop rigs) and our deployment target.
RTX_3080 = DeviceSpec(
    name="rtx-3080", tdp_w=320.0, static_w=28.0,
    peak_flops=29.8e12, hbm_bw=760e9, link_bw=16e9,   # fp32 shader peak, PCIe4 x16
    hbm_bytes=10 * 2**30,
)
RTX_3090 = DeviceSpec(
    name="rtx-3090", tdp_w=350.0, static_w=32.0,
    peak_flops=35.6e12, hbm_bw=936e9, link_bw=16e9,
    hbm_bytes=24 * 2**30,
)
# TPU v5e chip — constants from the assignment brief (197 TFLOP/s bf16,
# 819 GB/s HBM, ~50 GB/s/link ICI).  Board power is not officially published
# per chip; 215 W max / 75 W static are our documented assumptions
# (DESIGN.md Sec 5) in line with public v4 measurements scaled to v5e.
TPU_V5E = DeviceSpec(
    name="tpu-v5e", tdp_w=215.0, static_w=75.0,
    peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
    vmem_bytes=16 * 2**20, hbm_bytes=16 * 2**30,
)

DEVICES: dict[str, DeviceSpec] = {d.name: d for d in (RTX_3080, RTX_3090, TPU_V5E)}


# --------------------------------------------------------------------------
# Workload roofline description
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Per-step workload character, derivable from ``compiled.cost_analysis()``
    plus the HLO collective parse (see repro.launch.dryrun)."""
    name: str
    flops_per_step: float
    hbm_bytes_per_step: float
    collective_bytes_per_step: float = 0.0
    host_overhead_s: float = 0.0       # launch/data-pipeline serial time
    samples_per_step: int = 1
    overlap: float = 0.7               # 0 = fully serial terms, 1 = perfect overlap

    def roofline_times(self, spec: DeviceSpec) -> tuple[float, float, float]:
        t_c = self.flops_per_step / (spec.peak_flops * spec.matmul_efficiency)
        t_m = self.hbm_bytes_per_step / spec.hbm_bw
        t_x = self.collective_bytes_per_step / spec.link_bw
        return t_c, t_m, t_x

    def compute_fraction(self, spec: DeviceSpec) -> float:
        t_c, t_m, t_x = self.roofline_times(spec)
        tot = t_c + t_m + t_x + self.host_overhead_s
        return t_c / tot if tot > 0 else 0.0


# --------------------------------------------------------------------------
# The capped device
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StepEstimate:
    cap: float
    clock: float               # normalised f_hat
    step_time_s: float
    power_w: float             # board draw during the step
    energy_j: float            # per step
    utilization: float         # compute duty cycle


class PowerCappedDevice:
    """Analytic stand-in for one accelerator under a power cap.

    ``derate`` < 1 models thermal throttling / silicon lottery — the
    canonical straggler source the cluster power-shift allocator handles.
    """

    def __init__(self, spec: DeviceSpec, *, derate: float = 1.0):
        if not (0.0 < derate <= 1.0):
            raise ValueError("derate must be in (0, 1]")
        self.spec = spec
        self.derate = derate

    # -- governor -----------------------------------------------------------
    def clock_under_cap(self, cap: float, utilization: float) -> float:
        """Largest stable normalised clock meeting the cap at duty cycle u."""
        spec = self.spec
        cap = float(np.clip(cap, spec.min_cap, 1.0))
        budget = cap * spec.tdp_w - spec.static_w
        dyn_full = max(utilization, 1e-6) * (spec.tdp_w - spec.static_w)
        if budget <= 0.0:
            f = spec.min_clock
        else:
            f = min(1.0, (budget / dyn_full) ** (1.0 / 3.0))
        return max(f, spec.min_clock) * self.derate

    # -- step estimation ------------------------------------------------------
    def estimate(self, wl: WorkloadProfile, cap: float = 1.0) -> StepEstimate:
        spec = self.spec
        cap = float(np.clip(cap, spec.min_cap, 1.0))
        t_c, t_m, t_x = wl.roofline_times(spec)

        # Duty cycle and clock are mutually dependent (slower clock -> higher
        # compute fraction); a short fixed-point iteration converges fast.
        f = 1.0 * self.derate
        u = 0.0
        for _ in range(8):
            t_core_serial = t_c / f + t_m + t_x
            t_core_max = max(t_c / f, t_m, t_x)
            t_core = (1.0 - wl.overlap) * t_core_serial + wl.overlap * t_core_max
            step = t_core + wl.host_overhead_s
            u_new = (t_c / f) / step if step > 0 else 0.0
            f_new = self.clock_under_cap(cap, u_new)
            if abs(f_new - f) < 1e-6 and abs(u_new - u) < 1e-6:
                f, u = f_new, u_new
                break
            f, u = f_new, u_new

        t_core_serial = t_c / f + t_m + t_x
        t_core_max = max(t_c / f, t_m, t_x)
        t_core = (1.0 - wl.overlap) * t_core_serial + wl.overlap * t_core_max
        step_time = t_core + wl.host_overhead_s
        u = (t_c / f) / step_time if step_time > 0 else 0.0

        # Board draw: static + utilisation-weighted dynamic power at clock f,
        # with a light "active idle" term (boosted clocks while kernels are
        # resident draw power even when the MXU/SMs stall on memory).
        mem_duty = min(1.0, (t_m + t_x) / step_time) if step_time > 0 else 0.0
        dyn = (self.spec.tdp_w - self.spec.static_w)
        draw = (self.spec.static_w
                + u * dyn * f ** 3
                + 0.18 * mem_duty * dyn * f)        # memory-system + uncore draw
        draw = min(draw, cap * self.spec.tdp_w)     # governor guarantees the cap
        return StepEstimate(
            cap=cap, clock=f, step_time_s=step_time, power_w=draw,
            energy_j=draw * step_time, utilization=u,
        )

    # -- convenience ----------------------------------------------------------
    def probe(self, wl: WorkloadProfile, cap: float, duration_s: float) -> tuple[int, float, float]:
        """Run the workload under ``cap`` for ~``duration_s`` (simulated):
        returns (samples, energy_j, elapsed_s).  Mirrors one 30 s profiler
        probe (paper Sec III-C)."""
        est = self.estimate(wl, cap)
        n_steps = max(1, int(duration_s / max(est.step_time_s, 1e-9)))
        elapsed = n_steps * est.step_time_s
        return n_steps * wl.samples_per_step, est.energy_j * n_steps, elapsed
