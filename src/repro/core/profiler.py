"""The FROST cap profiler — paper Sec III-C.

When a new (model, dataset, hardware) triple appears, FROST:

  1. probes the 8 power limits {30..100}% of TDP for ~30 s each,
  2. computes the ED^mP cost of each probe (m from the A1 QoS policy),
  3. fits F(x) = a e^(bx-c) + d sigma(ex-f) + g by MSE (Eqs 6-7),
  4. minimises F with the downhill simplex -> optimal cap,
  5. applies the cap through a pluggable enforcement backend.

The workload is abstracted behind ``Workload.probe`` so the same profiler
drives: the analytic device model (this container), a real-step-timed CPU
workload (CNN zoo benchmarks), or `nvidia-smi`-backed hardware (deployment).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence

import numpy as np

from repro.core.edp import CapMeasurement, normalized_costs
from repro.core.energy import EnergyLedger
from repro.core.fitting import FitResult, fit_cost_curve, minimize_fit
from repro.core.policy import QoSPolicy

DEFAULT_CAP_GRID: tuple[float, ...] = tuple(np.round(np.arange(0.30, 1.001, 0.10), 2))
DEFAULT_PROBE_SECONDS = 30.0   # paper: ~30 s covers several batches for all models


class Workload(Protocol):
    """Anything FROST can profile."""

    def probe(self, cap: float, duration_s: float) -> tuple[int, float, float]:
        """Run under ``cap`` for ~``duration_s``; return
        (samples_processed, energy_joules, elapsed_seconds)."""
        ...


class CapBackend(Protocol):
    """Cap enforcement (``nvidia-smi -pl`` equivalent)."""

    def apply_cap(self, cap: float) -> None: ...
    def current_cap(self) -> float: ...


class RecordingBackend:
    """Default in-memory backend (simulation / dry deployments)."""

    def __init__(self) -> None:
        self._cap = 1.0
        self.history: list[float] = []

    def apply_cap(self, cap: float) -> None:
        self._cap = float(cap)
        self.history.append(self._cap)

    def current_cap(self) -> float:
        return self._cap


@dataclasses.dataclass(frozen=True)
class CapDecision:
    """Outcome of one profiling pass."""
    cap: float                         # selected power limit (fraction of TDP)
    policy_id: str
    edp_exponent: float
    fit: FitResult
    measurements: tuple[CapMeasurement, ...]
    profile_energy_j: float            # Eq 4/5 leading term: 8 * int P_pr dt
    predicted_energy_saving: float     # vs the 100% cap probe
    predicted_delay_increase: float    # vs the 100% cap probe

    @property
    def fit_accepted(self) -> bool:
        return self.fit.accepted


class CapProfiler:
    def __init__(
        self,
        workload: Workload,
        *,
        policy: QoSPolicy | None = None,
        backend: CapBackend | None = None,
        cap_grid: Sequence[float] = DEFAULT_CAP_GRID,
        probe_seconds: float = DEFAULT_PROBE_SECONDS,
        ledger: EnergyLedger | None = None,
    ) -> None:
        self.workload = workload
        self.policy = policy or QoSPolicy()
        self.backend = backend or RecordingBackend()
        self.cap_grid = tuple(sorted(float(c) for c in cap_grid))
        self.probe_seconds = float(probe_seconds)
        self.ledger = ledger

    # -- step 1-2: probe the grid -------------------------------------------
    def measure(self) -> list[CapMeasurement]:
        out: list[CapMeasurement] = []
        for cap in self.cap_grid:
            if not (self.policy.min_cap <= cap <= self.policy.max_cap):
                continue
            self.backend.apply_cap(cap)
            samples, energy_j, elapsed_s = self.workload.probe(cap, self.probe_seconds)
            out.append(CapMeasurement(cap=cap, energy_j=energy_j,
                                      delay_s=elapsed_s, samples=samples))
            if self.ledger is not None:
                self.ledger.add_profile_energy(energy_j)
        if len(out) < 3:
            raise RuntimeError("policy cap window leaves <3 probes; cannot profile")
        return out

    # -- step 3-5: fit, minimise, decide --------------------------------------
    def decide(self, measurements: Sequence[CapMeasurement]) -> CapDecision:
        m = self.policy.edp_exponent
        meas = sorted(measurements, key=lambda r: r.cap)
        caps = np.array([r.cap for r in meas])
        costs = normalized_costs(list(meas), m)
        fit = fit_cost_curve(caps, costs)
        best_cap, _ = minimize_fit(fit, lo=max(self.policy.min_cap, caps.min()),
                                   hi=min(self.policy.max_cap, caps.max()))

        ref = meas[-1]  # 100% (or highest legal) cap
        pred = self._interp(meas, best_cap)
        delay_increase = pred[1] / ref.time_per_sample - 1.0

        # Hard QoS constraint: walk the cap up until the delay bound holds.
        if (self.policy.max_delay_increase is not None
                and delay_increase > self.policy.max_delay_increase):
            for cap in [c for c in caps if c >= best_cap]:
                e, t = self._interp(meas, cap)
                if t / ref.time_per_sample - 1.0 <= self.policy.max_delay_increase:
                    best_cap, pred, delay_increase = cap, (e, t), t / ref.time_per_sample - 1.0
                    break
            else:
                best_cap, pred, delay_increase = ref.cap, (ref.energy_per_sample,
                                                           ref.time_per_sample), 0.0

        decision = CapDecision(
            cap=float(best_cap),
            policy_id=self.policy.policy_id,
            edp_exponent=m,
            fit=fit,
            measurements=tuple(meas),
            profile_energy_j=float(sum(r.energy_j for r in meas)),
            predicted_energy_saving=1.0 - pred[0] / ref.energy_per_sample,
            predicted_delay_increase=float(delay_increase),
        )
        self.backend.apply_cap(decision.cap)
        return decision

    def run(self) -> CapDecision:
        return self.decide(self.measure())

    @staticmethod
    def _interp(meas: Sequence[CapMeasurement], cap: float) -> tuple[float, float]:
        """Linear interpolation of (energy/sample, time/sample) between probes."""
        caps = np.array([r.cap for r in meas])
        e = np.array([r.energy_per_sample for r in meas])
        t = np.array([r.time_per_sample for r in meas])
        return (float(np.interp(cap, caps, e)), float(np.interp(cap, caps, t)))
