"""The FROST cap profiler — paper Sec III-C.

When a new (model, dataset, hardware) triple appears, FROST:

  1. probes the 8 power limits {30..100}% of TDP for ~30 s each,
  2. computes the ED^mP cost of each probe (m from the A1 QoS policy),
  3. fits F(x) = a e^(bx-c) + d sigma(ex-f) + g by MSE (Eqs 6-7),
  4. minimises F with the downhill simplex -> optimal cap,
  5. applies the cap through a pluggable enforcement backend.

The workload is abstracted behind ``Workload.probe`` so the same profiler
drives: the analytic device model (this container), a real-step-timed CPU
workload (CNN zoo benchmarks), or `nvidia-smi`-backed hardware (deployment).

Steps 2-4 are pure and shared with the event-driven online profiler
(``repro.control.online``) through :func:`decide_cap`; ``CapProfiler`` is
the batch front-end (dedicated probe windows) and publishes ``CapApplied``
events when attached to a control-plane bus.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Protocol, Sequence

import numpy as np

from repro.control.events import CapApplied
from repro.core.edp import CapMeasurement, normalized_costs
from repro.core.energy import EnergyLedger
from repro.core.fitting import FitResult, fit_cost_curve, minimize_fit
from repro.core.policy import QoSPolicy

if TYPE_CHECKING:
    from repro.control.bus import EventBus

DEFAULT_CAP_GRID: tuple[float, ...] = tuple(np.round(np.arange(0.30, 1.001, 0.10), 2))
DEFAULT_PROBE_SECONDS = 30.0   # paper: ~30 s covers several batches for all models


class Workload(Protocol):
    """Anything FROST can profile."""

    def probe(self, cap: float, duration_s: float) -> tuple[int, float, float]:
        """Run under ``cap`` for ~``duration_s``; return
        (samples_processed, energy_joules, elapsed_seconds)."""
        ...


class CapBackend(Protocol):
    """Cap enforcement (``nvidia-smi -pl`` equivalent)."""

    def apply_cap(self, cap: float) -> None: ...
    def current_cap(self) -> float: ...


class RecordingBackend:
    """Default in-memory backend (simulation / dry deployments)."""

    def __init__(self) -> None:
        self._cap = 1.0
        self.history: list[float] = []

    def apply_cap(self, cap: float) -> None:
        self._cap = float(cap)
        self.history.append(self._cap)

    def current_cap(self) -> float:
        return self._cap


@dataclasses.dataclass(frozen=True)
class CapDecision:
    """Outcome of one profiling pass."""
    cap: float                         # selected power limit (fraction of TDP)
    policy_id: str
    edp_exponent: float
    fit: FitResult
    measurements: tuple[CapMeasurement, ...]
    profile_energy_j: float            # Eq 4/5 leading term: 8 * int P_pr dt
    predicted_energy_saving: float     # vs the 100% cap probe
    predicted_delay_increase: float    # vs the 100% cap probe

    @property
    def fit_accepted(self) -> bool:
        return self.fit.accepted


def interp_measurements(meas: Sequence[CapMeasurement],
                         cap: float) -> tuple[float, float]:
    """Linear interpolation of (energy/sample, time/sample) between probes."""
    caps = np.array([r.cap for r in meas])
    e = np.array([r.energy_per_sample for r in meas])
    t = np.array([r.time_per_sample for r in meas])
    return (float(np.interp(cap, caps, e)), float(np.interp(cap, caps, t)))


def decide_cap(measurements: Sequence[CapMeasurement],
               policy: QoSPolicy,
               *,
               fit_x0: Sequence[float] | None = None,
               fit_multi_start: bool = True) -> CapDecision:
    """Steps 3-4 of the FROST flow as a pure function: fit F(x) to the probe
    costs, minimise over the policy's legal cap window, and enforce the hard
    QoS delay bound.  Shared by the batch ``CapProfiler`` and the streaming
    ``repro.control.online.OnlineCapProfiler`` (which warm-starts the fit
    from its previous coefficients via ``fit_x0``)."""
    if len(measurements) < 3:
        raise ValueError("need >=3 probes to decide a cap")
    m = policy.edp_exponent
    meas = sorted(measurements, key=lambda r: r.cap)
    caps = np.array([r.cap for r in meas])
    costs = normalized_costs(list(meas), m)
    fit = fit_cost_curve(caps, costs, x0=fit_x0, multi_start=fit_multi_start)
    best_cap, _ = minimize_fit(fit, lo=max(policy.min_cap, caps.min()),
                               hi=min(policy.max_cap, caps.max()))

    ref = meas[-1]  # 100% (or highest legal) cap
    pred = interp_measurements(meas, best_cap)
    delay_increase = pred[1] / ref.time_per_sample - 1.0

    # Hard QoS constraint: walk the cap up until the delay bound holds.
    if (policy.max_delay_increase is not None
            and delay_increase > policy.max_delay_increase):
        for cap in [c for c in caps if c >= best_cap]:
            e, t = interp_measurements(meas, cap)
            if t / ref.time_per_sample - 1.0 <= policy.max_delay_increase:
                best_cap, pred = cap, (e, t)
                delay_increase = t / ref.time_per_sample - 1.0
                break
        else:
            best_cap, pred, delay_increase = ref.cap, (ref.energy_per_sample,
                                                       ref.time_per_sample), 0.0

    return CapDecision(
        cap=float(best_cap),
        policy_id=policy.policy_id,
        edp_exponent=m,
        fit=fit,
        measurements=tuple(meas),
        profile_energy_j=float(sum(r.energy_j for r in meas)),
        predicted_energy_saving=1.0 - pred[0] / ref.energy_per_sample,
        predicted_delay_increase=float(delay_increase),
    )


class CapProfiler:
    def __init__(
        self,
        workload: Workload,
        *,
        policy: QoSPolicy | None = None,
        backend: CapBackend | None = None,
        cap_grid: Sequence[float] = DEFAULT_CAP_GRID,
        probe_seconds: float = DEFAULT_PROBE_SECONDS,
        ledger: EnergyLedger | None = None,
        bus: "EventBus | None" = None,
        node_id: str = "node-0",
    ) -> None:
        self.workload = workload
        self.policy = policy or QoSPolicy()
        self.backend = backend or RecordingBackend()
        self.cap_grid = tuple(sorted(float(c) for c in cap_grid))
        self.probe_seconds = float(probe_seconds)
        self.ledger = ledger
        self.bus = bus
        self.node_id = node_id

    def _apply(self, cap: float, reason: str) -> None:
        self.backend.apply_cap(cap)
        if self.bus is not None:
            self.bus.publish(CapApplied(node_id=self.node_id, cap=float(cap),
                                        reason=reason, source="cap-profiler"))

    # -- step 1-2: probe the grid -------------------------------------------
    def measure(self) -> list[CapMeasurement]:
        out: list[CapMeasurement] = []
        for cap in self.cap_grid:
            if not (self.policy.min_cap <= cap <= self.policy.max_cap):
                continue
            self._apply(cap, "probe")
            samples, energy_j, elapsed_s = self.workload.probe(cap, self.probe_seconds)
            out.append(CapMeasurement(cap=cap, energy_j=energy_j,
                                      delay_s=elapsed_s, samples=samples))
            if self.ledger is not None:
                self.ledger.add_profile_energy(energy_j)
        if len(out) < 3:
            raise RuntimeError("policy cap window leaves <3 probes; cannot profile")
        return out

    # -- step 3-5: fit, minimise, decide --------------------------------------
    def decide(self, measurements: Sequence[CapMeasurement]) -> CapDecision:
        decision = decide_cap(measurements, self.policy)
        self._apply(decision.cap, "decision")
        return decision

    def run(self) -> CapDecision:
        return self.decide(self.measure())

    @staticmethod
    def _interp(meas: Sequence[CapMeasurement], cap: float) -> tuple[float, float]:
        return interp_measurements(meas, cap)
