"""FROST as an O-RAN microservice — paper Fig 1 / Sec II-B.

Pragmatic, in-process realisation of the O-RAN AI/ML lifecycle pieces FROST
touches.  Each ML-enabled node runs a ``FrostService``; the SMO pushes A1
policies; new models trigger a profiling pass; the selected cap is applied
through the node's enforcement backend; continuous monitoring re-profiles
on drift (a changed workload invalidates the cached decision).

No network stack is emulated — the interfaces are plain method calls with
the same message shapes (A1 policy docs are dicts), so the service can be
lifted onto a real message bus unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

from repro.core.profiler import CapBackend, CapDecision, CapProfiler, RecordingBackend, Workload
from repro.core.policy import QoSPolicy


@dataclasses.dataclass
class CatalogueEntry:
    """AI/ML catalogue record (validated model ready for deployment)."""
    model_id: str
    metadata: Mapping[str, Any]
    cap_decision: CapDecision | None = None


class ModelCatalogue:
    """The non-RT-RIC AI/ML catalogue (validated + published models)."""

    def __init__(self) -> None:
        self._entries: dict[str, CatalogueEntry] = {}

    def publish(self, model_id: str, metadata: Mapping[str, Any] | None = None) -> CatalogueEntry:
        entry = CatalogueEntry(model_id=model_id, metadata=dict(metadata or {}))
        self._entries[model_id] = entry
        return entry

    def get(self, model_id: str) -> CatalogueEntry:
        return self._entries[model_id]

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)


@dataclasses.dataclass(frozen=True)
class MonitorEvent:
    ts: float
    kind: str           # "profiled" | "policy" | "drift" | "applied"
    detail: Mapping[str, Any]


class FrostService:
    """One per ML-enabled O-RAN node (inference host or training host)."""

    def __init__(
        self,
        node_id: str,
        *,
        backend: CapBackend | None = None,
        policy: QoSPolicy | None = None,
        probe_seconds: float = 30.0,
        drift_threshold: float = 0.15,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.node_id = node_id
        self.backend = backend or RecordingBackend()
        self.policy = policy or QoSPolicy()
        self.probe_seconds = probe_seconds
        self.drift_threshold = drift_threshold
        self._clock = clock
        self._decisions: dict[str, CapDecision] = {}
        self._baseline_step_time: dict[str, float] = {}
        self.events: list[MonitorEvent] = []

    # -- A1 policy ingestion (SMO -> non-RT-RIC -> node) ---------------------
    def on_policy(self, a1_doc: Mapping[str, Any]) -> QoSPolicy:
        self.policy = QoSPolicy.from_a1(a1_doc)
        self._decisions.clear()       # policy change invalidates cached caps
        self._log("policy", {"policy_id": self.policy.policy_id})
        return self.policy

    # -- model arrival (deployment from the catalogue) ------------------------
    def on_new_model(self, model_id: str, workload: Workload) -> CapDecision:
        profiler = CapProfiler(
            workload, policy=self.policy, backend=self.backend,
            probe_seconds=self.probe_seconds,
        )
        decision = profiler.run()
        self._decisions[model_id] = decision
        ref = max(decision.measurements, key=lambda r: r.cap)
        self._baseline_step_time[model_id] = ref.time_per_sample
        self._log("profiled", {
            "model": model_id, "cap": decision.cap,
            "saving": decision.predicted_energy_saving,
            "delay": decision.predicted_delay_increase,
            "fit_accepted": decision.fit_accepted,
        })
        return decision

    # -- continuous operation (O-RAN step vi) ---------------------------------
    def on_step_report(self, model_id: str, time_per_sample: float,
                       workload: Workload | None = None) -> CapDecision | None:
        """Monitoring hook: if observed throughput drifts >threshold from the
        profiled expectation, re-profile (workload changed under us)."""
        decision = self._decisions.get(model_id)
        if decision is None:
            return None
        expected = self._interp_time(decision, decision.cap)
        if expected <= 0:
            return None
        drift = abs(time_per_sample - expected) / expected
        if drift > self.drift_threshold and workload is not None:
            self._log("drift", {"model": model_id, "drift": drift})
            return self.on_new_model(model_id, workload)
        return None

    def decision_for(self, model_id: str) -> CapDecision | None:
        return self._decisions.get(model_id)

    @staticmethod
    def _interp_time(decision: CapDecision, cap: float) -> float:
        import numpy as np
        caps = np.array([r.cap for r in decision.measurements])
        t = np.array([r.time_per_sample for r in decision.measurements])
        return float(np.interp(cap, caps, t))

    def _log(self, kind: str, detail: Mapping[str, Any]) -> None:
        self.events.append(MonitorEvent(ts=self._clock(), kind=kind, detail=dict(detail)))
