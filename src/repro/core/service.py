"""FROST as an O-RAN microservice — paper Fig 1 / Sec II-B.

Pragmatic, in-process realisation of the O-RAN AI/ML lifecycle pieces FROST
touches.  Each ML-enabled node runs a ``FrostService``; the SMO pushes A1
policies; new models trigger a profiling pass; the selected cap is applied
through the node's enforcement backend; continuous monitoring re-profiles
on drift (a changed workload invalidates the cached decision).

Since the control-plane refactor the service is a thin adapter over the
event bus: ``attach(bus)`` subscribes it to ``StepDone`` (drift monitoring
— no more manual ``on_step_report`` plumbing) and ``PolicyUpdated`` (A1
ingestion), and every lifecycle action is published as a typed event.  The
direct-call API (``on_policy`` / ``on_new_model`` / ``on_step_report``)
keeps working unchanged for batch scripts and existing tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Callable, Mapping

from repro.control.events import DriftDetected, PolicyUpdated, StepDone
from repro.core.profiler import (CapBackend, CapDecision, CapProfiler,
                                 RecordingBackend, Workload,
                                 interp_measurements)
from repro.core.policy import QoSPolicy

if TYPE_CHECKING:
    from repro.control.bus import EventBus


@dataclasses.dataclass
class CatalogueEntry:
    """AI/ML catalogue record (validated model ready for deployment)."""
    model_id: str
    metadata: Mapping[str, Any]
    cap_decision: CapDecision | None = None


class ModelCatalogue:
    """The non-RT-RIC AI/ML catalogue (validated + published models)."""

    def __init__(self) -> None:
        self._entries: dict[str, CatalogueEntry] = {}

    def publish(self, model_id: str, metadata: Mapping[str, Any] | None = None) -> CatalogueEntry:
        entry = CatalogueEntry(model_id=model_id, metadata=dict(metadata or {}))
        self._entries[model_id] = entry
        return entry

    def get(self, model_id: str) -> CatalogueEntry:
        return self._entries[model_id]

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)


@dataclasses.dataclass(frozen=True)
class MonitorEvent:
    ts: float
    kind: str           # "profiled" | "policy" | "drift" | "applied"
    detail: Mapping[str, Any]


class FrostService:
    """One per ML-enabled O-RAN node (inference host or training host)."""

    def __init__(
        self,
        node_id: str,
        *,
        backend: CapBackend | None = None,
        policy: QoSPolicy | None = None,
        probe_seconds: float = 30.0,
        drift_threshold: float = 0.15,
        clock: Callable[[], float] = time.monotonic,
        bus: "EventBus | None" = None,
        reprofile_on_drift: bool = True,
    ) -> None:
        self.node_id = node_id
        self.backend = backend or RecordingBackend()
        self.policy = policy or QoSPolicy()
        self.probe_seconds = probe_seconds
        self.drift_threshold = drift_threshold
        self.reprofile_on_drift = reprofile_on_drift
        self._clock = clock
        self._decisions: dict[str, CapDecision] = {}
        self._workloads: dict[str, Workload] = {}
        self._baseline_step_time: dict[str, float] = {}
        self.events: list[MonitorEvent] = []
        self.bus: "EventBus | None" = None
        self._unsubs: list[Callable[[], None]] = []
        if bus is not None:
            self.attach(bus)

    # -- control-plane wiring -------------------------------------------------
    def attach(self, bus: "EventBus") -> "FrostService":
        """Subscribe to the control plane: ``StepDone`` events feed the drift
        monitor; ``PolicyUpdated`` events (from the SMO / coordinator) replace
        direct ``on_policy`` calls.

        NOTE: drift handling runs a full *batch* re-profile (8 dedicated
        probe windows of ``probe_seconds`` each) synchronously inside the
        publishing step's ``bus.publish`` — the seed's ``on_step_report``
        semantics, now automated.  On live traffic that stall is usually
        unacceptable: either pass ``reprofile_on_drift=False`` (the service
        then only publishes ``DriftDetected`` and leaves retuning to an
        ``OnlineCapProfiler``, which amortises probes across steps), or keep
        ``probe_seconds`` short."""
        self.detach()
        self.bus = bus
        self._unsubs = [
            bus.subscribe(StepDone, self._on_step_event),
            bus.subscribe(PolicyUpdated, self._on_policy_event),
        ]
        return self

    def detach(self) -> None:
        for u in self._unsubs:
            u()
        self._unsubs = []
        self.bus = None

    def _on_step_event(self, ev: StepDone) -> None:
        if ev.node_id != self.node_id or not ev.model_id:
            return
        self.on_step_report(ev.model_id, ev.duration_s / max(ev.samples, 1))

    def _on_policy_event(self, ev: PolicyUpdated) -> None:
        if ev.node_id != self.node_id:
            return
        if ev.policy is not self.policy:      # ignore our own publication
            # Adopt without re-publishing: echoing a second PolicyUpdated
            # would make every co-subscribed controller (e.g. an
            # OnlineCapProfiler) process each policy change twice.
            self._adopt_policy(QoSPolicy.from_a1(ev.policy.to_a1()),
                               publish=False)

    def _publish(self, event) -> None:
        if self.bus is not None:
            self.bus.publish(event)

    # -- A1 policy ingestion (SMO -> non-RT-RIC -> node) ---------------------
    def on_policy(self, a1_doc: Mapping[str, Any]) -> QoSPolicy:
        return self._adopt_policy(QoSPolicy.from_a1(a1_doc), publish=True)

    def _adopt_policy(self, policy: QoSPolicy, *, publish: bool) -> QoSPolicy:
        self.policy = policy
        self._decisions.clear()       # policy change invalidates cached caps
        self._log("policy", {"policy_id": self.policy.policy_id})
        if publish:
            self._publish(PolicyUpdated(node_id=self.node_id,
                                        policy=self.policy))
        return self.policy

    # -- model arrival (deployment from the catalogue) ------------------------
    def on_new_model(self, model_id: str, workload: Workload) -> CapDecision:
        # Route the profiler through the bus too: every probe/decision cap it
        # enforces on the backend shows up as a CapApplied event, so lossless
        # observers (bus.tap) see the real mid-run enforcement actions.
        profiler = CapProfiler(
            workload, policy=self.policy, backend=self.backend,
            probe_seconds=self.probe_seconds,
            bus=self.bus, node_id=self.node_id,
        )
        decision = profiler.run()
        self._decisions[model_id] = decision
        self._workloads[model_id] = workload
        ref = max(decision.measurements, key=lambda r: r.cap)
        self._baseline_step_time[model_id] = ref.time_per_sample
        self._log("profiled", {
            "model": model_id, "cap": decision.cap,
            "saving": decision.predicted_energy_saving,
            "delay": decision.predicted_delay_increase,
            "fit_accepted": decision.fit_accepted,
        })
        # CapApplied events (probes + decision) were published by the
        # profiler itself — publishing another here would double-count.
        return decision

    # -- continuous operation (O-RAN step vi) ---------------------------------
    def on_step_report(self, model_id: str, time_per_sample: float,
                       workload: Workload | None = None) -> CapDecision | None:
        """Monitoring hook: if observed throughput drifts >threshold from the
        profiled expectation, re-profile (workload changed under us).  The
        workload argument is optional when the model arrived via
        ``on_new_model`` (the service remembers how to probe it)."""
        decision = self._decisions.get(model_id)
        if decision is None:
            return None
        expected = self._interp_time(decision, decision.cap)
        if expected <= 0:
            return None
        drift = abs(time_per_sample - expected) / expected
        workload = workload if workload is not None \
            else self._workloads.get(model_id)
        if drift > self.drift_threshold:
            self._log("drift", {"model": model_id, "drift": drift})
            self._publish(DriftDetected(
                node_id=self.node_id, model_id=model_id, drift=float(drift),
                expected_s=float(expected), observed_s=float(time_per_sample)))
            if self.reprofile_on_drift and workload is not None:
                return self.on_new_model(model_id, workload)
        return None

    def decision_for(self, model_id: str) -> CapDecision | None:
        return self._decisions.get(model_id)

    @staticmethod
    def _interp_time(decision: CapDecision, cap: float) -> float:
        return interp_measurements(decision.measurements, cap)[1]

    def _log(self, kind: str, detail: Mapping[str, Any]) -> None:
        self.events.append(MonitorEvent(ts=self._clock(), kind=kind, detail=dict(detail)))
