"""Fitting the profiler cost curve — paper Eqs (6)-(7).

    F(x) = a * exp(b*x - c) + d * sigmoid(e*x - f) + g

fitted to the 8 probe costs by minimising mean squared error.  The paper
accepts the fit when the error drops below 5%; we implement the same gate
(relative RMSE against the spread of y) and fall back to the best measured
probe when the gate fails — a mis-fit curve must never pick a cap no probe
supports (robustness requirement from O-RAN's reliability mandate).

scipy is unavailable; the MSE minimisation reuses the same downhill-simplex
engine the paper uses for the final curve minimisation, with multi-start
initialisation to avoid local minima of the 7-coefficient landscape.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.simplex import minimize_scalar_on_interval, nelder_mead

_COEF_NAMES = ("a", "b", "c", "d", "e", "f", "g")


def sigmoid(x: np.ndarray | float) -> np.ndarray | float:
    return 1.0 / (1.0 + np.exp(-np.clip(x, -60.0, 60.0)))


def f_curve(x: np.ndarray | float, coef: Sequence[float]) -> np.ndarray | float:
    """Paper Eq (6)."""
    a, b, c, d, e, f, g = coef
    z = np.clip(np.asarray(b * np.asarray(x) - c, dtype=np.float64), -60.0, 60.0)
    return a * np.exp(z) + d * sigmoid(e * np.asarray(x) - f) + g


@dataclasses.dataclass(frozen=True)
class FitResult:
    coef: tuple[float, ...]          # (a, b, c, d, e, f, g)
    rel_rmse: float                  # fit error, relative (paper's <5% gate)
    accepted: bool                   # rel_rmse < gate
    x: np.ndarray                    # probe caps
    y: np.ndarray                    # probe costs (normalised ED^mP)

    def __call__(self, x: np.ndarray | float) -> np.ndarray | float:
        return f_curve(x, self.coef)

    @property
    def coef_dict(self) -> dict[str, float]:
        return dict(zip(_COEF_NAMES, self.coef))


def _mse(coef: np.ndarray, x: np.ndarray, y: np.ndarray) -> float:
    r = f_curve(x, coef) - y
    return float(np.mean(r * r))


def _initial_guesses(x: np.ndarray, y: np.ndarray) -> list[np.ndarray]:
    """Heuristic multi-start seeds.

    The empirical curve (paper Fig 4/5) falls steeply below ~40% cap
    (exponential term, b < 0: instability/compute-bound blow-up at deep
    caps) and rises gently toward 100% (sigmoid term): seeds cover both
    orientations plus a flat curve (LeNet-like outliers).
    """
    y_span = max(float(y.max() - y.min()), 1e-9)
    y_mid = float(np.median(y))
    x_mid = float(np.median(x))
    seeds = [
        # decaying exponential from the left + rising sigmoid to the right
        np.array([y_span, -8.0, -8.0 * x.min(), y_span, 8.0, 8.0 * x_mid, y_mid]),
        # gentler variant
        np.array([y_span / 2, -4.0, -4.0 * x.min(), y_span / 2, 4.0, 4.0 * x_mid, y_mid]),
        # rising exponential toward the right + falling sigmoid
        np.array([y_span / 4, 4.0, 4.0 * x.max(), -y_span, 6.0, 6.0 * x_mid, y_mid]),
        # nearly flat
        np.array([0.0, 1.0, 1.0, 0.0, 1.0, 1.0, y_mid]),
    ]
    return seeds


def fit_cost_curve(
    caps: Sequence[float],
    costs: Sequence[float],
    *,
    error_gate: float = 0.05,
    max_iter: int = 4000,
    x0: Sequence[float] | None = None,
    multi_start: bool = True,
) -> FitResult:
    """Fit Eq (6) to (cap, ED^mP) probes by MSE (Eq 7).

    ``x0`` warm-starts the simplex from known-good coefficients (e.g. the
    previous fit in the online profiler's incremental refits); with
    ``multi_start=False`` only that start (plus its polish) runs — an order
    of magnitude cheaper, appropriate when the probe data moved slightly.
    """
    x = np.asarray(caps, dtype=np.float64)
    y = np.asarray(costs, dtype=np.float64)
    if x.size != y.size or x.size < 3:
        raise ValueError("need >=3 (cap, cost) probes")

    seeds: list[np.ndarray] = []
    if x0 is not None:
        seeds.append(np.asarray(x0, dtype=np.float64))
    if multi_start or not seeds:
        seeds.extend(_initial_guesses(x, y))

    best: tuple[float, np.ndarray] | None = None
    for seed in seeds:
        res = nelder_mead(lambda c: _mse(c, x, y), seed,
                          initial_step=0.25, max_iter=max_iter,
                          xatol=1e-10, fatol=1e-14)
        if best is None or res.fun < best[0]:
            best = (res.fun, res.x)
        # polish the winner from a perturbed restart
        res2 = nelder_mead(lambda c: _mse(c, x, y), best[1] * 1.05 + 1e-3,
                           initial_step=0.05, max_iter=max_iter,
                           xatol=1e-10, fatol=1e-14)
        if res2.fun < best[0]:
            best = (res2.fun, res2.x)

    mse = best[0]
    # Paper: "if the error drops below 5%, we consider the line a good fit".
    # Interpreted as RMSE relative to the dynamic range of the probes (scale-
    # free; the probes themselves are already normalised ED^mP values).
    scale = max(float(np.max(np.abs(y))), 1e-12)
    rel_rmse = float(np.sqrt(mse)) / scale
    return FitResult(
        coef=tuple(float(v) for v in best[1]),
        rel_rmse=rel_rmse,
        accepted=rel_rmse < error_gate,
        x=x,
        y=y,
    )


def minimize_fit(
    fit: FitResult,
    lo: float = 0.3,
    hi: float = 1.0,
) -> tuple[float, float]:
    """Minimise the fitted F(x) over the legal cap range with the downhill
    simplex (paper Sec III-C).  Falls back to the best *measured* probe when
    the fit failed its acceptance gate."""
    if not fit.accepted:
        i = int(np.argmin(fit.y))
        return float(fit.x[i]), float(fit.y[i])
    return minimize_scalar_on_interval(lambda x: float(f_curve(x, fit.coef)), lo, hi)
