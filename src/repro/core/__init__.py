"""FROST core — the paper's contribution as a composable library.

Energy accounting (Eqs 1-5), ED^mP metrics, the F(x) cost-curve fit
(Eqs 6-7), the downhill-simplex minimiser, the 8-point cap profiler, QoS
policies, device power models, cluster power shifting, and the O-RAN
service wrapper.
"""
from repro.core.edp import CapMeasurement, edp, normalized_costs
from repro.core.energy import (EnergyLedger, EnergyReport, PowerSample,
                               dram_power_estimate, integrate_power)
from repro.core.fitting import FitResult, f_curve, fit_cost_curve, minimize_fit
from repro.core.policy import BALANCED, ENERGY_LEAN, LATENCY_LEAN, QoSPolicy
from repro.core.powermodel import (DEVICES, RTX_3080, RTX_3090, TPU_V5E,
                                   DeviceSpec, PowerCappedDevice, StepEstimate,
                                   WorkloadProfile)
from repro.core.powershift import (ClusterNode, NodeAllocation, ShiftPlan,
                                   allocate_power, detect_stragglers)
from repro.core.profiler import (DEFAULT_CAP_GRID, CapDecision, CapProfiler,
                                 RecordingBackend, decide_cap,
                                 interp_measurements)
from repro.core.service import FrostService, ModelCatalogue
from repro.core.simplex import SimplexResult, minimize_scalar_on_interval, nelder_mead

__all__ = [
    "CapMeasurement", "edp", "normalized_costs",
    "EnergyLedger", "EnergyReport", "PowerSample", "dram_power_estimate",
    "integrate_power",
    "FitResult", "f_curve", "fit_cost_curve", "minimize_fit",
    "QoSPolicy", "ENERGY_LEAN", "BALANCED", "LATENCY_LEAN",
    "DeviceSpec", "PowerCappedDevice", "StepEstimate", "WorkloadProfile",
    "DEVICES", "RTX_3080", "RTX_3090", "TPU_V5E",
    "ClusterNode", "NodeAllocation", "ShiftPlan", "allocate_power",
    "detect_stragglers",
    "CapDecision", "CapProfiler", "RecordingBackend", "DEFAULT_CAP_GRID",
    "decide_cap", "interp_measurements",
    "FrostService", "ModelCatalogue",
    "SimplexResult", "nelder_mead", "minimize_scalar_on_interval",
]
