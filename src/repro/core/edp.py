"""Energy-Delay Product metrics — paper Sec III-C.

EDP = E * D bridges algorithm and hardware (Gonzalez & Horowitz 1996).  The
paper generalises to ED^m P so A1 policies can weight delay:

    m = 1  -> energy-lean    (max energy savings)
    m = 2  -> the paper's empirical sweet spot (Fig 6)
    m = 3  -> delay-lean     (optimal cap drifts toward 100%)
"""
from __future__ import annotations

import dataclasses

import numpy as np


def edp(energy_j: float, delay_s: float, m: float = 1.0) -> float:
    """Generalised energy-delay product  E * D^m."""
    if energy_j < 0 or delay_s < 0:
        raise ValueError("energy and delay must be non-negative")
    return float(energy_j) * float(delay_s) ** float(m)


@dataclasses.dataclass(frozen=True)
class CapMeasurement:
    """One profiler probe result at a given power cap."""
    cap: float                 # fraction of TDP in [0.3, 1.0]
    energy_j: float            # net probe energy (idle-subtracted)
    delay_s: float             # time to process the probe workload
    samples: int = 0           # workload items processed during the probe

    @property
    def energy_per_sample(self) -> float:
        return self.energy_j / self.samples if self.samples else self.energy_j

    @property
    def time_per_sample(self) -> float:
        return self.delay_s / self.samples if self.samples else self.delay_s

    def cost(self, m: float = 1.0) -> float:
        """ED^mP on per-sample quantities so probes of different lengths
        compare fairly (the paper normalises by the energy-per-sample)."""
        return edp(self.energy_per_sample, self.time_per_sample, m)


def normalized_costs(measurements: list[CapMeasurement], m: float) -> np.ndarray:
    """ED^mP of each probe, normalised by the 100%-cap (or max-cap) probe so
    the fitted curve is scale-free."""
    if not measurements:
        raise ValueError("no measurements")
    ref = max(measurements, key=lambda r: r.cap)
    ref_cost = ref.cost(m)
    if ref_cost <= 0:
        raise ValueError("reference probe has non-positive cost")
    return np.array([r.cost(m) / ref_cost for r in measurements])
