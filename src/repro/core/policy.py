"""QoS policies — paper Sec III-C last paragraph.

In O-RAN, the A1 Policy Management Service pushes declarative policies from
the non-RT-RIC to the apps.  FROST consumes a small policy document that
selects the ED^mP exponent (and optional hard constraints) per use case:

    {"policy_id": "...", "edp_exponent": 2, "max_delay_increase": 0.10,
     "min_cap": 0.3, "scope": {"node": "...", "model": "..."}}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping


@dataclasses.dataclass(frozen=True)
class QoSPolicy:
    """Decision policy for cap selection."""
    policy_id: str = "default-ed2p"
    edp_exponent: float = 2.0          # paper: ED^2P is the sweet spot (Fig 6)
    max_delay_increase: float | None = None   # e.g. 0.10 -> at most +10% step time
    min_cap: float = 0.30              # never below the instability floor
    max_cap: float = 1.00
    scope: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.edp_exponent < 0:
            raise ValueError("edp_exponent must be >= 0")
        if not (0.0 < self.min_cap <= self.max_cap <= 1.0):
            raise ValueError("need 0 < min_cap <= max_cap <= 1")
        if self.max_delay_increase is not None and self.max_delay_increase < 0:
            raise ValueError("max_delay_increase must be >= 0")

    # -- A1-style (de)serialisation ----------------------------------------
    @classmethod
    def from_a1(cls, doc: Mapping[str, Any]) -> "QoSPolicy":
        return cls(
            policy_id=str(doc.get("policy_id", "unnamed")),
            edp_exponent=float(doc.get("edp_exponent", 2.0)),
            max_delay_increase=(None if doc.get("max_delay_increase") is None
                                else float(doc["max_delay_increase"])),
            min_cap=float(doc.get("min_cap", 0.30)),
            max_cap=float(doc.get("max_cap", 1.00)),
            scope=dict(doc.get("scope", {})),
        )

    def to_a1(self) -> dict[str, Any]:
        return {
            "policy_id": self.policy_id,
            "edp_exponent": self.edp_exponent,
            "max_delay_increase": self.max_delay_increase,
            "min_cap": self.min_cap,
            "max_cap": self.max_cap,
            "scope": dict(self.scope),
        }


ENERGY_LEAN = QoSPolicy(policy_id="energy-lean-ed1p", edp_exponent=1.0)
BALANCED = QoSPolicy(policy_id="balanced-ed2p", edp_exponent=2.0)
LATENCY_LEAN = QoSPolicy(policy_id="latency-lean-ed3p", edp_exponent=3.0)
