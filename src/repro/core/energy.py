"""Energy accounting — paper Sec III-A/B, Eqs (1)-(5).

The paper measures phase energy as the integral of sampled power minus the
integral of idle power over a fixed idle-measurement window ``T_m``, and adds
the cost of the 8 profiling probes when the profiler ran (Eqs 4-5):

    E_tr = 8 * int_0^{T_pr} P_pr dt  +  int_0^{T_tr} P_tr dt  -  int_0^{T_m} P_idle dt

Power at any instant is the component sum P_CPU + P_GPU + P_DRAM (Eq 3).
DRAM power uses the paper's rule of thumb  P_DRAM = N_DIMM * 3/8 * S_DIMM
(watts, S_DIMM in GB) since consumer CPUs expose no DRAM MSRs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PowerSample:
    """One telemetry sample (paper Eq 3 components), watts."""
    t: float          # seconds, monotonic
    cpu_w: float = 0.0
    gpu_w: float = 0.0
    dram_w: float = 0.0

    @property
    def total_w(self) -> float:
        return self.cpu_w + self.gpu_w + self.dram_w


def dram_power_estimate(n_dimm: int, dimm_size_gb: float) -> float:
    """Paper Sec III-A: P_DRAM = N_DIMM x 3/8 x S_DIMM (load-independent)."""
    if n_dimm < 0 or dimm_size_gb < 0:
        raise ValueError("DIMM count/size must be non-negative")
    return n_dimm * (3.0 / 8.0) * dimm_size_gb


def integrate_power(samples: Sequence[PowerSample]) -> float:
    """Trapezoidal integral of total power over the sample trace -> joules."""
    if len(samples) < 2:
        return 0.0
    t = np.array([s.t for s in samples])
    p = np.array([s.total_w for s in samples])
    if np.any(np.diff(t) < 0):
        raise ValueError("power samples must be time-ordered")
    return float(np.trapezoid(p, t))


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Net energy of one pipeline phase (training or inference)."""
    gross_j: float            # int P_phase dt
    idle_j: float             # int_0^{T_m} P_idle dt  (subtracted, Eq 1/2)
    profile_j: float          # 8 * int P_pr dt        (added, Eq 4/5)
    duration_s: float

    @property
    def net_j(self) -> float:
        return self.profile_j + self.gross_j - self.idle_j

    @property
    def mean_power_w(self) -> float:
        # Paper Sec IV-A: P_tr = E_tr / T_tr.
        return self.gross_j / self.duration_s if self.duration_s > 0 else 0.0


class EnergyLedger:
    """Accumulates telemetry for one phase and produces an EnergyReport.

    Mirrors the FROST measurement flow: an idle trace is captured once per
    host (window T_m), each profiler probe contributes its own trace, and
    the phase trace is integrated at the end.
    """

    def __init__(self, idle_trace: Sequence[PowerSample] | None = None):
        self._idle_trace: list[PowerSample] = list(idle_trace or [])
        self._phase: list[PowerSample] = []
        self._profile_j: float = 0.0

    # -- telemetry ingestion ------------------------------------------------
    def record(self, sample: PowerSample) -> None:
        self._phase.append(sample)

    def extend(self, samples: Iterable[PowerSample]) -> None:
        self._phase.extend(samples)

    def record_idle(self, sample: PowerSample) -> None:
        self._idle_trace.append(sample)

    def add_profile_probe(self, probe_trace: Sequence[PowerSample]) -> None:
        """One of the 8 profiler probes (Eq 4/5 leading term)."""
        self._profile_j += integrate_power(probe_trace)

    def add_profile_energy(self, joules: float) -> None:
        self._profile_j += float(joules)

    # -- reporting ----------------------------------------------------------
    @property
    def idle_power_w(self) -> float:
        if len(self._idle_trace) < 2:
            return 0.0
        dur = self._idle_trace[-1].t - self._idle_trace[0].t
        return integrate_power(self._idle_trace) / dur if dur > 0 else 0.0

    def report(self) -> EnergyReport:
        dur = (self._phase[-1].t - self._phase[0].t) if len(self._phase) >= 2 else 0.0
        # Idle subtraction uses the phase duration at the measured idle power
        # (the paper's T_m idle window calibrates P_idle; the subtraction is
        # over the phase span).
        idle_j = self.idle_power_w * dur
        return EnergyReport(
            gross_j=integrate_power(self._phase),
            idle_j=idle_j,
            profile_j=self._profile_j,
            duration_s=dur,
        )
