"""Downhill-simplex (Nelder-Mead) minimiser.

The paper (Sec III-C) uses "the downhill simplex algorithm" to find the
minimum of the fitted cost curve F(x).  scipy is not available in this
environment, so we carry a small, dependency-free implementation that is
also reused by the curve fitter (`repro.core.fitting`).

Implements the adaptive-parameter variant (Gao & Han 2012), which behaves
better in higher dimensions (the F(x) fit has 7 coefficients).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class SimplexResult:
    x: Array                 # argmin found
    fun: float               # value at x
    n_iter: int
    n_eval: int
    converged: bool

    def __iter__(self):      # convenience unpacking: x, fun = nelder_mead(...)
        yield self.x
        yield self.fun


def nelder_mead(
    f: Callable[[Array], float],
    x0: Sequence[float],
    *,
    initial_step: float | Sequence[float] = 0.1,
    max_iter: int = 2000,
    xatol: float = 1e-8,
    fatol: float = 1e-10,
    bounds: Sequence[tuple[float, float]] | None = None,
) -> SimplexResult:
    """Minimise ``f`` starting from ``x0``.

    ``bounds`` are enforced by clipping candidate points (projection), which
    is adequate for the smooth, low-dimensional objectives used here.
    """
    x0 = np.asarray(x0, dtype=np.float64)
    n = x0.size
    if bounds is not None:
        lo = np.array([b[0] for b in bounds], dtype=np.float64)
        hi = np.array([b[1] for b in bounds], dtype=np.float64)
        clip = lambda x: np.clip(x, lo, hi)  # noqa: E731
    else:
        clip = lambda x: x  # noqa: E731

    # Adaptive coefficients (Gao & Han).
    alpha = 1.0
    beta = 1.0 + 2.0 / n
    gamma = 0.75 - 1.0 / (2.0 * n)
    delta = 1.0 - 1.0 / n

    steps = np.broadcast_to(np.asarray(initial_step, dtype=np.float64), (n,))
    simplex = np.empty((n + 1, n), dtype=np.float64)
    simplex[0] = clip(x0)
    for i in range(n):
        v = x0.copy()
        v[i] += steps[i]
        simplex[i + 1] = clip(v)

    n_eval = 0

    def feval(x: Array) -> float:
        nonlocal n_eval
        n_eval += 1
        val = float(f(x))
        if not np.isfinite(val):
            return 1e300
        return val

    fvals = np.array([feval(v) for v in simplex])

    n_iter = 0
    converged = False
    while n_iter < max_iter:
        n_iter += 1
        order = np.argsort(fvals, kind="stable")
        simplex, fvals = simplex[order], fvals[order]

        if (np.max(np.abs(simplex[1:] - simplex[0])) <= xatol
                and np.max(np.abs(fvals[1:] - fvals[0])) <= fatol):
            converged = True
            break

        centroid = simplex[:-1].mean(axis=0)
        worst = simplex[-1]

        xr = clip(centroid + alpha * (centroid - worst))
        fr = feval(xr)
        if fr < fvals[0]:
            xe = clip(centroid + beta * (xr - centroid))
            fe = feval(xe)
            if fe < fr:
                simplex[-1], fvals[-1] = xe, fe
            else:
                simplex[-1], fvals[-1] = xr, fr
        elif fr < fvals[-2]:
            simplex[-1], fvals[-1] = xr, fr
        else:
            if fr < fvals[-1]:  # outside contraction
                xc = clip(centroid + gamma * (xr - centroid))
                fc = feval(xc)
                accept = fc <= fr
            else:               # inside contraction
                xc = clip(centroid - gamma * (centroid - worst))
                fc = feval(xc)
                accept = fc < fvals[-1]
            if accept:
                simplex[-1], fvals[-1] = xc, fc
            else:               # shrink towards best
                for i in range(1, n + 1):
                    simplex[i] = clip(simplex[0] + delta * (simplex[i] - simplex[0]))
                    fvals[i] = feval(simplex[i])

    order = np.argsort(fvals, kind="stable")
    return SimplexResult(
        x=simplex[order[0]].copy(),
        fun=float(fvals[order[0]]),
        n_iter=n_iter,
        n_eval=n_eval,
        converged=converged,
    )


def minimize_scalar_on_interval(
    f: Callable[[float], float],
    lo: float,
    hi: float,
    *,
    coarse_points: int = 71,
) -> tuple[float, float]:
    """Global-ish scalar minimisation: coarse grid scan (the paper's Fig 5
    uses 1% increments) followed by a Nelder-Mead polish from the best
    grid point.  Returns (argmin, min)."""
    xs = np.linspace(lo, hi, coarse_points)
    ys = np.array([float(f(x)) for x in xs])
    i = int(np.argmin(ys))
    res = nelder_mead(lambda v: f(float(v[0])), [xs[i]],
                      initial_step=(hi - lo) / (2 * coarse_points),
                      bounds=[(lo, hi)], max_iter=200)
    if res.fun <= ys[i]:
        return float(res.x[0]), float(res.fun)
    return float(xs[i]), float(ys[i])
