"""Cluster-level power shifting — paper Sec II-C, built out (beyond paper).

The paper motivates power *shifting* ("dynamic setting of power budgets for
individual system components to maintain a global power level") but only
evaluates single nodes.  At pod scale this becomes the straggler problem:
in synchronous data parallelism the step time is the max over ranks, so a
naive uniform cap wastes the budget on fast nodes while a derated node
drags the pod.  The allocator below:

  1. models every node as a PowerCappedDevice (possibly heterogeneous or
     thermally derated),
  2. finds, by bisection on the target step time T, the per-node caps that
     just achieve T, subject to  sum_i cap_i * TDP_i <= global_budget,
  3. returns per-node caps: slow nodes get more power, fast nodes are
     capped harder — equalising step time (straggler mitigation) at
     minimum energy.

This is the FROST-native alternative to dropping stragglers from the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.powermodel import PowerCappedDevice, WorkloadProfile


@dataclasses.dataclass(frozen=True)
class NodeAllocation:
    node_id: str
    cap: float
    power_w: float
    step_time_s: float
    energy_per_step_j: float


@dataclasses.dataclass(frozen=True)
class ShiftPlan:
    allocations: tuple[NodeAllocation, ...]
    step_time_s: float            # synchronous step time = max over ranks
    total_power_w: float
    global_budget_w: float
    feasible: bool

    @property
    def energy_per_step_j(self) -> float:
        # Synchronous DP: every rank is powered for the full step (idle
        # ranks still draw; we charge the allocated power for max-T).
        return sum(a.power_w for a in self.allocations) * self.step_time_s


@dataclasses.dataclass
class ClusterNode:
    node_id: str
    device: PowerCappedDevice
    workload: WorkloadProfile

    def step_time(self, cap: float) -> float:
        return self.device.estimate(self.workload, cap).step_time_s

    def min_cap_for_step_time(self, target_s: float) -> float:
        """Smallest cap achieving step_time <= target (monotone -> bisect)."""
        spec = self.device.spec
        lo, hi = spec.min_cap, 1.0
        if self.step_time(hi) > target_s:
            return float("inf")          # infeasible even uncapped
        if self.step_time(lo) <= target_s:
            return lo
        for _ in range(40):
            mid = 0.5 * (lo + hi)
            if self.step_time(mid) <= target_s:
                hi = mid
            else:
                lo = mid
        return hi


def allocate_power(
    nodes: Sequence[ClusterNode],
    global_budget_w: float,
    *,
    tol: float = 1e-3,
) -> ShiftPlan:
    """Minimise synchronous step time subject to the global power budget.

    Outer bisection on the step-time target T; inner per-node bisection for
    the cheapest cap achieving T.  Both are monotone, so this converges to
    the water-filling optimum.
    """
    if not nodes:
        raise ValueError("no nodes")

    def budget_for(target_s: float) -> tuple[float, list[float]]:
        caps = [n.min_cap_for_step_time(target_s) for n in nodes]
        if any(np.isinf(c) for c in caps):
            return float("inf"), caps
        watts = sum(c * n.device.spec.tdp_w for c, n in zip(caps, nodes))
        return watts, caps

    # Fastest possible step time: all nodes uncapped.
    t_min = max(n.step_time(1.0) for n in nodes)
    w_at_tmin, _ = budget_for(t_min)
    feasible = True
    if w_at_tmin <= global_budget_w:
        t_star = t_min
    else:
        # Slowest sensible target: everyone at min cap.
        t_max = max(n.step_time(n.device.spec.min_cap) for n in nodes)
        w_at_tmax, _ = budget_for(t_max)
        if w_at_tmax > global_budget_w:
            feasible = False              # budget below floor: best effort
            t_star = t_max
        else:
            lo, hi = t_min, t_max
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                w, _ = budget_for(mid)
                if w <= global_budget_w:
                    hi = mid
                else:
                    lo = mid
                if hi - lo < tol * t_min:
                    break
            t_star = hi

    _, caps = budget_for(t_star)
    caps = [min(max(c, n.device.spec.min_cap), 1.0) for c, n in zip(caps, nodes)]
    allocs = []
    for n, c in zip(nodes, caps):
        est = n.device.estimate(n.workload, c)
        allocs.append(NodeAllocation(node_id=n.node_id, cap=c, power_w=est.power_w,
                                     step_time_s=est.step_time_s,
                                     energy_per_step_j=est.energy_j))
    step_time = max(a.step_time_s for a in allocs)
    return ShiftPlan(
        allocations=tuple(allocs),
        step_time_s=step_time,
        total_power_w=sum(a.power_w for a in allocs),
        global_budget_w=float(global_budget_w),
        feasible=feasible,
    )


def detect_stragglers(
    step_times_s: Sequence[float],
    *,
    threshold: float = 1.3,
) -> list[int]:
    """Indices of ranks slower than ``threshold`` x median — the supervisor
    feeds these into allocate_power (shift watts toward them) before ever
    considering evicting the node."""
    t = np.asarray(step_times_s, dtype=np.float64)
    med = float(np.median(t))
    if med <= 0:
        return []
    return [i for i, v in enumerate(t) if v > threshold * med]
