"""Single-token decode attention as split-K Pallas TPU kernels — one for the
canonical ring-buffer cache, one for a paged (block-table) cache.

Decode is the memory-bound end of the serving stack (PAPER.md Sec IV: the
whole KV cache streams HBM -> VMEM once per generated token, against one
query row of compute), so the kernel's only job is to touch each cache byte
exactly once, in its storage dtype, and keep every reduction in on-chip
fp32 scratch:

  * grid = (batch, q_heads, k_blocks) — split-K over KV-cache blocks: for a
    fixed (b, h) the kernel revisits the same single-row output tile while
    streaming ``decode_k_chunk``-sized k/v blocks; the online-softmax
    partial state (m, l, acc) lives in fp32 VMEM scratch across those
    revolutions, exactly as in ``flash_attention.py``.
  * GQA is folded into the k/v index_map (q head h reads kv head
    h // (Hq // Hkv)) — no kv replication in HBM.
  * the cache is a *ring buffer*: slot s holds absolute position
    ``pos - ((pos - s) mod C)``.  That mapping is recomputed from a
    block-relative iota inside the kernel, so validity (slot not yet
    written => negative position) and the sliding window are masked without
    materialising a position array in HBM.
  * ``pos`` arrives via scalar prefetch (SMEM) so the masks are dynamic;
    blocks whose slots are wholly past ``pos`` (ring not yet wrapped) are
    predicated off with ``pl.when`` — no MXU work, and on real hardware a
    grid prune would skip their DMA too.
  * k/v blocks are cast to fp32 only inside VMEM (block-local); the HBM
    cache stays in storage dtype — the whole-cache fp32 cast this kernel
    replaces tripled decode HBM traffic.

Both layouts also carry a *multi-query verify* variant for speculative
decoding (``verify_attention_pallas`` / ``paged_verify_attention_pallas``):
``q_len = K+1`` query rows share ONE cache sweep, the causal offset masks
fold into the same iota/pos machinery, and the fed block's own k/v arrive
as a separate in-flight input folded at the last grid step — speculative
candidates never land in HBM, so rejection needs no cache rollback.

Validated in interpret mode against ``kernels/ref.decode_attention_ref``
and ``ops.decode_attention_jnp`` (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _online_softmax_update(q, k, v, valid, m_ref, l_ref, acc_ref, *,
                           scale: float, logit_cap: float):
    """One k/v block's online-softmax update into the fp32 VMEM
    accumulators — the numerically delicate core shared by every kernel in
    this module (single-token and multi-query, ring and paged).

    q: (R, D) query rows; k: (bk, D); v: (bk, Dv); valid: (R, bk);
    m/l: (R,); acc: (R, Dv)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _fold_candidates_and_finish(q_ref, kn_ref, vn_ref, o_ref, m_ref, l_ref,
                                acc_ref, *, scale: float, window: int,
                                logit_cap: float, q_len: int):
    """Verify-kernel epilogue, shared by the ring and paged variants: fold
    the in-flight candidate block (causal within the fed tokens — query row
    i attends to candidates j <= i), then normalize into the output tile."""
    ri = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    cand_valid = cj <= ri
    if window > 0:
        cand_valid = jnp.logical_and(cand_valid, cj > ri - window)
    _online_softmax_update(
        q_ref[0, 0].astype(jnp.float32),
        kn_ref[0, 0].astype(jnp.float32),
        vn_ref[0, 0].astype(jnp.float32),
        cand_valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)
    l = jnp.maximum(l_ref[...], 1e-30)
    o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, window: int, logit_cap: float,
                   block_k: int, n_k: int, cache_len: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    # ring invariant: slot s holds absolute position pos - ((pos - s) mod C);
    # slots not yet written resolve to negative positions and mask off.
    slot = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    k_pos = pos - jnp.remainder(pos - slot, cache_len)
    valid = k_pos >= 0
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > pos - window)

    # blocks with no valid slot (wholly past pos, or wholly outside the
    # window) contribute nothing — skip their MXU work entirely
    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (1, D)
            k_ref[0, 0].astype(jnp.float32),                 # (bk, D)
            v_ref[0, 0].astype(jnp.float32),                 # (bk, Dv)
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)   ring buffer, storage dtype
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    pos: jax.Array,                # () int32 absolute position of q
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    block_k: int = 256, interpret: bool = False,
) -> jax.Array:
    """Split-K decode attention against the canonical ring-buffer cache
    (slot = p % C).  Assumes that invariant — callers with an arbitrary
    ``k_pos`` layout must use the jnp/ref paths."""
    B, _, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        # largest divisor of C that still fits the requested block: keeps the
        # split-K streaming (and its VMEM budget) instead of degrading to one
        # whole-cache block
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, logit_cap=logit_cap,
        block_k=block_k, n_k=n_k, cache_len=C)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, ik, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, pos_ref, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, ik, pos_ref, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dv),
                               lambda b, h, ik, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running denom l
            pltpu.VMEM((1, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, Dv), q.dtype),
        interpret=interpret,
    )(pos_arr, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)             # (B, 1, Hq, Dv)


def _verify_kernel(pos_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, window: int,
                   logit_cap: float, block_k: int, n_k: int, cache_len: int,
                   q_len: int):
    """Multi-query speculative verify against the ring cache.

    Same split-K streaming as ``_decode_kernel`` but with ``q_len = K+1``
    query rows sharing one cache sweep — the online-softmax state is per
    query row.  Query row i sits at absolute position ``pos + i``; the
    cache is committed through ``pos - 1`` and the fed block's own k/v
    arrive as a separate in-flight input (``kn/vn``) folded in at the last
    grid step, so nothing speculative ever lands in HBM.  Ring-eviction
    semantics (``k_pos > q_pos - C``) mask the entries the sequential loop
    would already have overwritten by query i."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, block_k), 0)
    q_pos = pos + qi
    slot = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (q_len, block_k), 1)
    last = pos - 1                    # committed through pos - 1
    k_pos = last - jnp.remainder(last - slot, cache_len)
    valid = (k_pos >= 0) & (k_pos > q_pos - cache_len)
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > q_pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (Q, D)
            k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ik == n_k - 1)
    def _finish():
        _fold_candidates_and_finish(
            q_ref, kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref,
            scale=scale, window=window, logit_cap=logit_cap, q_len=q_len)


def verify_attention_pallas(
    q: jax.Array,                  # (B, Q, Hq, D)   Q = K+1 fed tokens
    k_cache: jax.Array,            # (B, C, Hkv, D)  committed through pos-1
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)  in-flight candidate rows
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    pos: jax.Array,                # () int32 absolute position of q[:, 0]
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    block_k: int = 256, interpret: bool = False,
) -> jax.Array:
    """Split-K speculative verify attention against the canonical ring
    cache.  Assumes the ring invariant for the *committed* prefix (last
    write at ``(pos - 1) % C``); the fed block's candidates never touch the
    cache — rejection therefore needs no rollback."""
    B, Q, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if Q > C:
        raise ValueError(f"verify block {Q} exceeds cache capacity {C}")
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, Q, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, Dv)
    knt = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, D)
    vnt = v_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _verify_kernel, scale=scale, window=window, logit_cap=logit_cap,
        block_k=block_k, n_k=n_k, cache_len=C, q_len=Q)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, Q, D),
                         lambda b, h, ik, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, pos_ref, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, ik, pos_ref, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, Q, D),
                         lambda b, h, ik, pos_ref, G=G: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Q, Dv),
                         lambda b, h, ik, pos_ref, G=G: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, Dv),
                               lambda b, h, ik, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q,), jnp.float32),       # running max m, per query
            pltpu.VMEM((Q,), jnp.float32),       # running denom l
            pltpu.VMEM((Q, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Q, Dv), q.dtype),
        interpret=interpret,
    )(pos_arr, qt, kt, vt, knt, vnt)
    return out.transpose(0, 2, 1, 3)             # (B, Q, Hq, Dv)


def _paged_verify_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                         window: int, logit_cap: float, page_size: int,
                         n_blocks: int, q_len: int):
    """Paged analogue of ``_verify_kernel``: linear layout (no eviction
    mask), per-request ``pos``, block-table gather in the k/v index_map."""
    ib, ij = pl.program_id(0), pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, page_size), 0)
    q_pos = pos + qi
    k_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (q_len, page_size), 1)
    valid = k_pos < pos                # committed rows only
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > q_pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (Q, D)
            k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == n_blocks - 1)
    def _finish():
        _fold_candidates_and_finish(
            q_ref, kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref,
            scale=scale, window=window, logit_cap=logit_cap, q_len=q_len)


def paged_verify_attention_pallas(
    q: jax.Array,                  # (B, Q, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)    in-flight candidates
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) absolute position of q[:, 0]
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Split-K speculative verify attention over a paged KV cache: same
    block-table gather as ``paged_decode_attention_pallas``, ``q_len = K+1``
    query rows per (b, h) tile, in-flight candidates folded at the last
    grid step.  ``pos`` is per-request (ragged batch)."""
    B, Q, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, Q, D)
    kt = k_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vt = v_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, Dv)
    knt = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, D)
    vnt = v_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, Dv)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _paged_verify_kernel, scale=scale, window=window, logit_cap=logit_cap,
        page_size=ps, n_blocks=nb, q_len=Q)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, Hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, Q, D),
                         lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, j, bt_ref, pos_ref, G=G:
                         (bt_ref[b, j], h // G, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dv),
                         lambda b, h, j, bt_ref, pos_ref, G=G:
                         (bt_ref[b, j], h // G, 0, 0)),
            pl.BlockSpec((1, 1, Q, D),
                         lambda b, h, j, bt_ref, pos_ref, G=G:
                         (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Q, Dv),
                         lambda b, h, j, bt_ref, pos_ref, G=G:
                         (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, Dv),
                               lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q,), jnp.float32),       # running max m, per query
            pltpu.VMEM((Q,), jnp.float32),       # running denom l
            pltpu.VMEM((Q, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Q, Dv), q.dtype),
        interpret=interpret,
    )(bt, pos_arr, qt, kt, vt, knt, vnt)
    return out.transpose(0, 2, 1, 3)             # (B, Q, Hq, Dv)


def _paged_decode_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, window: int,
                         logit_cap: float, page_size: int, n_blocks: int):
    ib, ij = pl.program_id(0), pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # paged layout is *linear*: logical block j of request b holds absolute
    # positions [j*ps, (j+1)*ps) — no ring arithmetic, the block table alone
    # says where those positions live in the pool
    pos = pos_ref[ib]
    k_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = k_pos <= pos
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > pos - window)

    # blocks wholly beyond the request's length (or outside the window) are
    # predicated off — under partial occupancy most of the grid is this case
    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (1, D)
            k_ref[0, 0].astype(jnp.float32),                 # (ps, D)
            v_ref[0, 0].astype(jnp.float32),                 # (ps, Dv)
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Split-K decode attention over a paged KV cache.

    Same online-softmax accumulator discipline as the ring kernel, but the
    k/v ``index_map`` gathers through the scalar-prefetched block table:
    grid step ``(b, h, j)`` DMAs physical page ``block_tables[b, j]`` for kv
    head ``h // G``.  The pool is shared across requests — a request's pages
    need not be contiguous, only its table row must list them in logical
    order.  ``pos`` is per-request (ragged batch), so validity masks are
    per-row, unlike the ring kernel's single scalar."""
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vt = v_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, Dv)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=window, logit_cap=logit_cap,
        page_size=ps, n_blocks=nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, Hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, j, bt_ref, pos_ref, G=G:
                         (bt_ref[b, j], h // G, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dv),
                         lambda b, h, j, bt_ref, pos_ref, G=G:
                         (bt_ref[b, j], h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dv),
                               lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running denom l
            pltpu.VMEM((1, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, Dv), q.dtype),
        interpret=interpret,
    )(bt, pos_arr, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)             # (B, 1, Hq, Dv)
