"""Single-token decode attention as split-K Pallas TPU kernels — one for the
canonical ring-buffer cache, one for a paged (block-table) cache.

Decode is the memory-bound end of the serving stack (PAPER.md Sec IV: the
whole KV cache streams HBM -> VMEM once per generated token, against one
query row of compute), so the kernel's only job is to touch each cache byte
exactly once, in its storage dtype, and keep every reduction in on-chip
fp32 scratch:

  * grid = (batch, q_heads, k_blocks) — split-K over KV-cache blocks: for a
    fixed (b, h) the kernel revisits the same single-row output tile while
    streaming ``decode_k_chunk``-sized k/v blocks; the online-softmax
    partial state (m, l, acc) lives in fp32 VMEM scratch across those
    revolutions, exactly as in ``flash_attention.py``.
  * GQA is folded into the k/v index_map (q head h reads kv head
    h // (Hq // Hkv)) — no kv replication in HBM.
  * the cache is a *ring buffer*: slot s holds absolute position
    ``pos - ((pos - s) mod C)``.  That mapping is recomputed from a
    block-relative iota inside the kernel, so validity (slot not yet
    written => negative position) and the sliding window are masked without
    materialising a position array in HBM.
  * ``pos`` arrives via scalar prefetch (SMEM) so the masks are dynamic;
    blocks whose slots are wholly past ``pos`` (ring not yet wrapped) are
    predicated off with ``pl.when`` — no MXU work, and on real hardware a
    grid prune would skip their DMA too.
  * k/v blocks are cast to fp32 only inside VMEM (block-local); the HBM
    cache stays in storage dtype — the whole-cache fp32 cast this kernel
    replaces tripled decode HBM traffic.

Both layouts also carry a *multi-query verify* variant for speculative
decoding (``verify_attention_pallas`` / ``paged_verify_attention_pallas``):
``q_len = K+1`` query rows share ONE cache sweep, the causal offset masks
fold into the same iota/pos machinery, and the fed block's own k/v arrive
as a separate in-flight input folded at the last grid step — speculative
candidates never land in HBM, so rejection needs no cache rollback.

Every kernel family also has a *two-stage* form (``n_splits > 1``), the
flash-decoding shape for deep caches at low batch: stage 1 adds a
``num_kv_splits`` grid axis — each split independently sweeps its
contiguous slice of k-blocks/pages and writes a *normalized* partial
output plus the slice's log-sum-exp, with no cross-split scratch
dependency, so splits can run on different cores — and stage 2 is ONE
shared LSE-merge kernel (``merge_kv_splits_pallas``) doing the
numerically-exact online-softmax reduction over splits:

    out = sum_s partial_s * exp(lse_s - m*) / sum_s exp(lse_s - m*)

``n_splits = 1`` bypasses stage 2 entirely and is bit-for-bit today's
single-kernel sweep.  The split count is chosen by
``ops.choose_kv_splits`` (grid-occupancy heuristic) unless forced via
``KernelPolicy.kv_splits``.  See docs/decode_path.md ("Two-stage
split-KV").

Validated in interpret mode against ``kernels/ref.decode_attention_ref``
and ``ops.decode_attention_jnp`` (tests/test_kernels.py,
tests/test_split_kv.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _online_softmax_update(q, k, v, valid, m_ref, l_ref, acc_ref, *,
                           scale: float, logit_cap: float):
    """One k/v block's online-softmax update into the fp32 VMEM
    accumulators — the numerically delicate core shared by every kernel in
    this module (single-token and multi-query, ring and paged).

    q: (R, D) query rows; k: (bk, D); v: (bk, Dv); valid: (R, bk);
    m/l: (R,); acc: (R, Dv)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _load_kv(ref, scale_ref):
    """Fused-dequant block load — the ONLY place quantization touches the
    sweep's critical path.  The k/v block is cast to fp32 inside VMEM
    (block-local, as always); for int8 pools the per-row fp32 scale block
    ``(bk, 1)`` rides the same index_map as its pool and multiplies in,
    broadcasting over head_dim.  ``scale_ref is None`` is a Python-level
    branch resolved at trace time: the unquantized kernels' traces are
    byte-for-byte what they were before int8 support existed."""
    blk = ref[0, 0].astype(jnp.float32)
    if scale_ref is not None:
        blk = blk * scale_ref[0, 0]
    return blk


def _fold_candidates(q_ref, kn_ref, vn_ref, m_ref, l_ref, acc_ref, *,
                     scale: float, window: int, logit_cap: float, q_len: int):
    """Fold the in-flight candidate block into the online-softmax scratch
    (causal within the fed tokens — query row i attends to candidates
    j <= i).  Shared by the single-stage verify epilogue and the two-stage
    verify kernels (which fold candidates into the LAST split only, keeping
    stage 2 a layout-agnostic LSE merge)."""
    ri = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    cand_valid = cj <= ri
    if window > 0:
        cand_valid = jnp.logical_and(cand_valid, cj > ri - window)
    _online_softmax_update(
        q_ref[0, 0].astype(jnp.float32),
        kn_ref[0, 0].astype(jnp.float32),
        vn_ref[0, 0].astype(jnp.float32),
        cand_valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)


def _fold_candidates_and_finish(q_ref, kn_ref, vn_ref, o_ref, m_ref, l_ref,
                                acc_ref, *, scale: float, window: int,
                                logit_cap: float, q_len: int):
    """Verify-kernel epilogue, shared by the ring and paged variants: fold
    the in-flight candidate block, then normalize into the output tile."""
    _fold_candidates(q_ref, kn_ref, vn_ref, m_ref, l_ref, acc_ref,
                     scale=scale, window=window, logit_cap=logit_cap,
                     q_len=q_len)
    l = jnp.maximum(l_ref[...], 1e-30)
    o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _write_partials(part_ref, lse_ref, m_ref, l_ref, acc_ref):
    """Stage-1 epilogue: flush this split's scratch as a *normalized*
    partial output plus its log-sum-exp.

    ``partial = acc / max(l, eps)`` and ``lse = m + log(l)`` make the
    stage-2 merge exact: ``partial_s * l_s e^{m_s} = acc_s e^{m_s}``, so
    weighting partials by ``softmax(lse)`` recovers the single-sweep
    softmax identically.  A split whose blocks were all masked (ring not
    yet wrapped, window, or the clamp padding of a non-divisible split
    count) still has ``l == 0`` — its lse is pinned to NEG_INF so the
    merge weighs it to exactly zero instead of NaN-ing on log(0)."""
    l = l_ref[...]
    part_ref[0, 0, 0] = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
    lse_ref[0, 0, 0] = jnp.where(
        l > 0.0, m_ref[...] + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)


def _lse_merge_kernel(part_ref, lse_ref, o_ref):
    """Stage 2: numerically-exact online-softmax reduction over splits.

    One (flattened batch*head) tile per grid step: renormalize every
    split's partial by its share of the global denominator.  All-empty
    rows (every lse == NEG_INF) degrade to a uniform average of partials —
    finite garbage, same contract as the single-stage kernels' masked-row
    behaviour."""
    lse = lse_ref[0]                                     # (S, R)
    m = jnp.max(lse, axis=0)                             # (R,)
    w = jnp.exp(lse - m[None, :])                        # (S, R)
    den = jnp.maximum(jnp.sum(w, axis=0), 1e-30)         # (R,)
    acc = jnp.sum(part_ref[0] * w[..., None], axis=0)    # (R, Dv)
    o_ref[0] = (acc / den[:, None]).astype(o_ref.dtype)


def merge_kv_splits_pallas(partial: jax.Array, lse: jax.Array, *,
                           out_dtype, interpret: bool = False) -> jax.Array:
    """Merge stage-1 split partials: ``partial (..., S, R, Dv)`` fp32 +
    ``lse (..., S, R)`` fp32 -> ``(..., R, Dv)`` in ``out_dtype``.

    The ONE stage-2 kernel shared by all four sweep families (ring/paged x
    decode/verify) and by the chunked-prefill path that reuses the paged
    verify sweep — the merge is layout-agnostic because stage 1 already
    folded every layout quirk (ring arithmetic, block tables, in-flight
    candidates) into the partial/lse contract."""
    lead = partial.shape[:-3]
    S, R, Dv = partial.shape[-3:]
    pf = partial.reshape((-1, S, R, Dv))
    lf = lse.reshape((-1, S, R))
    N = pf.shape[0]
    out = pl.pallas_call(
        _lse_merge_kernel,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, S, R, Dv), lambda n: (n, 0, 0, 0)),
                  pl.BlockSpec((1, S, R), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((1, R, Dv), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, R, Dv), out_dtype),
        interpret=interpret,
    )(pf, lf)
    return out.reshape(lead + (R, Dv))


def _split_blocks(n_blocks: int, n_splits: int) -> tuple[int, int]:
    """Clamp the split count to the block count and size each split's
    contiguous block slice (ceil — the last split may sweep fewer blocks
    when the counts don't divide)."""
    s = max(1, min(int(n_splits), n_blocks))
    return s, -(-n_blocks // s)


def _decode_kernel(pos_ref, *refs, scale: float, window: int,
                   logit_cap: float, block_k: int, n_k: int, cache_len: int,
                   quantized: bool = False, batch_pos: bool = False):
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # batch_pos: ragged batch of private ring buffers (windowed paged
    # layers) — each batch row decodes at its own position
    pos = pos_ref[pl.program_id(0)] if batch_pos else pos_ref[0]
    # ring invariant: slot s holds absolute position pos - ((pos - s) mod C);
    # slots not yet written resolve to negative positions and mask off.
    slot = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    k_pos = pos - jnp.remainder(pos - slot, cache_len)
    valid = k_pos >= 0
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > pos - window)

    # blocks with no valid slot (wholly past pos, or wholly outside the
    # window) contribute nothing — skip their MXU work entirely
    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (1, D)
            _load_kv(k_ref, ks_ref),                         # (bk, D)
            _load_kv(v_ref, vs_ref),                         # (bk, Dv)
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _decode_partials_kernel(pos_ref, *refs, scale: float,
                            window: int, logit_cap: float, block_k: int,
                            n_k: int, kpb: int, cache_len: int,
                            quantized: bool = False, batch_pos: bool = False):
    """Stage 1 of the two-stage ring decode sweep: grid
    ``(B, Hq, n_splits, kpb)``.  Split ``s`` owns global k-blocks
    ``[s*kpb, (s+1)*kpb)``; its scratch is private (init at local block 0,
    flushed as (partial, lse) at local block kpb-1) so splits have no
    cross-split dependency.  Blocks past ``n_k`` (non-divisible split
    counts — the index_map clamps their DMA to the last real block) mask
    off wholly."""
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    isp, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[pl.program_id(0)] if batch_pos else pos_ref[0]
    g = isp * kpb + ik                       # global k-block index
    slot = g * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    k_pos = pos - jnp.remainder(pos - slot, cache_len)
    valid = (k_pos >= 0) & (g < n_k)
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),
            _load_kv(k_ref, ks_ref),
            _load_kv(v_ref, vs_ref),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ik == kpb - 1)
    def _flush():
        _write_partials(part_ref, lse_ref, m_ref, l_ref, acc_ref)


def decode_attention_pallas_partials(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)   ring buffer, storage dtype
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    pos: jax.Array,                # () int32 absolute position of q
    *,
    n_splits: int, window: int = 0, logit_cap: float = 0.0,
    scale: float | None = None, block_k: int = 256, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Stage 1 only: per-split partial sweep over the ring cache.

    Returns ``(partial (B, Hq, S, 1, Dv) fp32, lse (B, Hq, S, 1) fp32)``
    — the two-stage contract validated against
    ``ref.decode_attention_split_ref``."""
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, _, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k
    n_splits, kpb = _split_blocks(n_k, n_splits)

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(-1)
    if pos_arr.shape[0] not in (1, B):
        raise ValueError(f"pos must be scalar or ({B},), got {pos_arr.shape}")

    kernel = functools.partial(
        _decode_partials_kernel, scale=scale, window=window,
        logit_cap=logit_cap, block_k=block_k, n_k=n_k, kpb=kpb, cache_len=C,
        quantized=quantized, batch_pos=pos_arr.shape[0] > 1)

    def kv_index(b, h, s, ik, pos_ref, G=G, kpb=kpb, n_k=n_k):
        # clamp out-of-range blocks of the ragged last split to a real
        # block: its DMA lands somewhere valid and the kernel masks it off
        return (b, h // G, jnp.minimum(s * kpb + ik, n_k - 1), 0)

    in_specs = [pl.BlockSpec((1, 1, 1, D),
                             lambda b, h, s, ik, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (B, Hkv, C, 1)
    in_specs.append(pl.BlockSpec((1, 1, block_k, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_splits, kpb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1, Dv),
                         lambda b, h, s, ik, pos_ref: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, h, s, ik, pos_ref: (b, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running denom l
            pltpu.VMEM((1, Dv), jnp.float32),    # running numerator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hq, n_splits, 1, Dv), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, n_splits, 1), jnp.float32)],
        interpret=interpret,
    )(pos_arr, *inputs)


def decode_attention_pallas(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)   ring buffer, storage dtype
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    pos: jax.Array,                # () int32 absolute position of q
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    block_k: int = 256, n_splits: int = 1, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Split-K decode attention against the canonical ring-buffer cache
    (slot = p % C).  Assumes that invariant — callers with an arbitrary
    ``k_pos`` layout must use the jnp/ref paths.  ``n_splits > 1`` runs
    the two-stage pipeline (parallel partial sweeps + LSE merge);
    ``n_splits = 1`` is the original single-kernel sweep, unchanged.
    ``k_scale``/``v_scale`` (per-row fp32) flag an int8 cache: the dequant
    fuses into the block load (``_load_kv``), nothing else changes.
    ``pos`` may be scalar (one shared position — the fused serve loop) or
    ``(B,)`` (ragged batch of private ring buffers — the paged engine's
    windowed layers, where each slot's ring is at its own position)."""
    if n_splits > 1:
        partial, lse = decode_attention_pallas_partials(
            q, k_cache, v_cache, pos, n_splits=n_splits, window=window,
            logit_cap=logit_cap, scale=scale, block_k=block_k,
            k_scale=k_scale, v_scale=v_scale, interpret=interpret)
        out = merge_kv_splits_pallas(partial, lse, out_dtype=q.dtype,
                                     interpret=interpret)   # (B, Hq, 1, Dv)
        return out.transpose(0, 2, 1, 3)                    # (B, 1, Hq, Dv)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, _, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        # largest divisor of C that still fits the requested block: keeps the
        # split-K streaming (and its VMEM budget) instead of degrading to one
        # whole-cache block
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(-1)
    if pos_arr.shape[0] not in (1, B):
        raise ValueError(f"pos must be scalar or ({B},), got {pos_arr.shape}")

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, logit_cap=logit_cap,
        block_k=block_k, n_k=n_k, cache_len=C, quantized=quantized,
        batch_pos=pos_arr.shape[0] > 1)

    def kv_index(b, h, ik, pos_ref, G=G):
        return (b, h // G, ik, 0)

    in_specs = [pl.BlockSpec((1, 1, 1, D),
                             lambda b, h, ik, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (B, Hkv, C, 1)
    in_specs.append(pl.BlockSpec((1, 1, block_k, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, Dv),
                               lambda b, h, ik, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running denom l
            pltpu.VMEM((1, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, Dv), q.dtype),
        interpret=interpret,
    )(pos_arr, *inputs)
    return out.transpose(0, 2, 1, 3)             # (B, 1, Hq, Dv)


def _verify_kernel(pos_ref, *refs, scale: float, window: int,
                   logit_cap: float, block_k: int, n_k: int, cache_len: int,
                   q_len: int, quantized: bool = False):
    """Multi-query speculative verify against the ring cache.

    Same split-K streaming as ``_decode_kernel`` but with ``q_len = K+1``
    query rows sharing one cache sweep — the online-softmax state is per
    query row.  Query row i sits at absolute position ``pos + i``; the
    cache is committed through ``pos - 1`` and the fed block's own k/v
    arrive as a separate in-flight input (``kn/vn``) folded in at the last
    grid step, so nothing speculative ever lands in HBM.  Ring-eviction
    semantics (``k_pos > q_pos - C``) mask the entries the sequential loop
    would already have overwritten by query i.  In quantized mode only the
    CACHE carries scales — the in-flight candidates stay unquantized."""
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref, kn_ref, vn_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, kn_ref, vn_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, block_k), 0)
    q_pos = pos + qi
    slot = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (q_len, block_k), 1)
    last = pos - 1                    # committed through pos - 1
    k_pos = last - jnp.remainder(last - slot, cache_len)
    valid = (k_pos >= 0) & (k_pos > q_pos - cache_len)
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > q_pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (Q, D)
            _load_kv(k_ref, ks_ref),
            _load_kv(v_ref, vs_ref),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ik == n_k - 1)
    def _finish():
        _fold_candidates_and_finish(
            q_ref, kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref,
            scale=scale, window=window, logit_cap=logit_cap, q_len=q_len)


def _verify_partials_kernel(pos_ref, *refs,
                            scale: float, window: int, logit_cap: float,
                            block_k: int, n_k: int, kpb: int, n_splits: int,
                            cache_len: int, q_len: int,
                            quantized: bool = False):
    """Stage 1 of the two-stage ring verify sweep.  Same masks as
    ``_verify_kernel``; the in-flight candidate block folds into the LAST
    split's scratch just before its flush, so stage 2 stays the generic
    LSE merge (no candidate-aware merge variant needed)."""
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref, kn_ref, vn_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, kn_ref, vn_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    isp, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    g = isp * kpb + ik
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, block_k), 0)
    q_pos = pos + qi
    slot = g * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (q_len, block_k), 1)
    last = pos - 1                    # committed through pos - 1
    k_pos = last - jnp.remainder(last - slot, cache_len)
    valid = (k_pos >= 0) & (k_pos > q_pos - cache_len) & (g < n_k)
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > q_pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (Q, D)
            _load_kv(k_ref, ks_ref),
            _load_kv(v_ref, vs_ref),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when((ik == kpb - 1) & (isp == n_splits - 1))
    def _fold():
        _fold_candidates(q_ref, kn_ref, vn_ref, m_ref, l_ref, acc_ref,
                         scale=scale, window=window, logit_cap=logit_cap,
                         q_len=q_len)

    @pl.when(ik == kpb - 1)
    def _flush():
        _write_partials(part_ref, lse_ref, m_ref, l_ref, acc_ref)


def verify_attention_pallas_partials(
    q: jax.Array,                  # (B, Q, Hq, D)   Q = K+1 fed tokens
    k_cache: jax.Array,            # (B, C, Hkv, D)  committed through pos-1
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)  in-flight candidate rows
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    pos: jax.Array,                # () int32 absolute position of q[:, 0]
    *,
    n_splits: int, window: int = 0, logit_cap: float = 0.0,
    scale: float | None = None, block_k: int = 256, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Stage 1 only: per-split verify sweep over the ring cache, candidates
    folded into the last split.  Returns ``(partial (B, Hq, S, Q, Dv) fp32,
    lse (B, Hq, S, Q) fp32)``."""
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, Q, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if Q > C:
        raise ValueError(f"verify block {Q} exceeds cache capacity {C}")
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k
    n_splits, kpb = _split_blocks(n_k, n_splits)

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, Q, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, Dv)
    knt = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, D)
    vnt = v_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _verify_partials_kernel, scale=scale, window=window,
        logit_cap=logit_cap, block_k=block_k, n_k=n_k, kpb=kpb,
        n_splits=n_splits, cache_len=C, q_len=Q, quantized=quantized)

    def kv_index(b, h, s, ik, pos_ref, G=G, kpb=kpb, n_k=n_k):
        return (b, h // G, jnp.minimum(s * kpb + ik, n_k - 1), 0)

    in_specs = [pl.BlockSpec((1, 1, Q, D),
                             lambda b, h, s, ik, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (B, Hkv, C, 1)
    in_specs.append(pl.BlockSpec((1, 1, block_k, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))
    in_specs += [
        pl.BlockSpec((1, 1, Q, D),
                     lambda b, h, s, ik, pos_ref, G=G: (b, h // G, 0, 0)),
        pl.BlockSpec((1, 1, Q, Dv),
                     lambda b, h, s, ik, pos_ref, G=G: (b, h // G, 0, 0)),
    ]
    inputs += [knt, vnt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_splits, kpb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, Dv),
                         lambda b, h, s, ik, pos_ref: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q),
                         lambda b, h, s, ik, pos_ref: (b, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q,), jnp.float32),       # running max m, per query
            pltpu.VMEM((Q,), jnp.float32),       # running denom l
            pltpu.VMEM((Q, Dv), jnp.float32),    # running numerator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hq, n_splits, Q, Dv), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, n_splits, Q), jnp.float32)],
        interpret=interpret,
    )(pos_arr, *inputs)


def verify_attention_pallas(
    q: jax.Array,                  # (B, Q, Hq, D)   Q = K+1 fed tokens
    k_cache: jax.Array,            # (B, C, Hkv, D)  committed through pos-1
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)  in-flight candidate rows
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    pos: jax.Array,                # () int32 absolute position of q[:, 0]
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    block_k: int = 256, n_splits: int = 1, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Split-K speculative verify attention against the canonical ring
    cache.  Assumes the ring invariant for the *committed* prefix (last
    write at ``(pos - 1) % C``); the fed block's candidates never touch the
    cache — rejection therefore needs no rollback.  ``n_splits > 1`` runs
    the two-stage pipeline; ``n_splits = 1`` is the original sweep.
    ``k_scale``/``v_scale`` flag an int8 cache (fused dequant in the block
    load); candidates are never quantized."""
    if n_splits > 1:
        partial, lse = verify_attention_pallas_partials(
            q, k_cache, v_cache, k_new, v_new, pos, n_splits=n_splits,
            window=window, logit_cap=logit_cap, scale=scale, block_k=block_k,
            k_scale=k_scale, v_scale=v_scale, interpret=interpret)
        out = merge_kv_splits_pallas(partial, lse, out_dtype=q.dtype,
                                     interpret=interpret)   # (B, Hq, Q, Dv)
        return out.transpose(0, 2, 1, 3)                    # (B, Q, Hq, Dv)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, Q, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if Q > C:
        raise ValueError(f"verify block {Q} exceeds cache capacity {C}")
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, Q, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, D)
    vt = v_cache.transpose(0, 2, 1, 3)           # (B, Hkv, C, Dv)
    knt = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, D)
    vnt = v_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, Dv)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _verify_kernel, scale=scale, window=window, logit_cap=logit_cap,
        block_k=block_k, n_k=n_k, cache_len=C, q_len=Q, quantized=quantized)

    def kv_index(b, h, ik, pos_ref, G=G):
        return (b, h // G, ik, 0)

    in_specs = [pl.BlockSpec((1, 1, Q, D),
                             lambda b, h, ik, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (B, Hkv, C, 1)
    in_specs.append(pl.BlockSpec((1, 1, block_k, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_k, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))
    in_specs += [
        pl.BlockSpec((1, 1, Q, D),
                     lambda b, h, ik, pos_ref, G=G: (b, h // G, 0, 0)),
        pl.BlockSpec((1, 1, Q, Dv),
                     lambda b, h, ik, pos_ref, G=G: (b, h // G, 0, 0)),
    ]
    inputs += [knt, vnt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Q, Dv),
                               lambda b, h, ik, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q,), jnp.float32),       # running max m, per query
            pltpu.VMEM((Q,), jnp.float32),       # running denom l
            pltpu.VMEM((Q, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Q, Dv), q.dtype),
        interpret=interpret,
    )(pos_arr, *inputs)
    return out.transpose(0, 2, 1, 3)             # (B, Q, Hq, Dv)


def _paged_verify_kernel(bt_ref, pos_ref, *refs, scale: float,
                         window: int, logit_cap: float, page_size: int,
                         n_blocks: int, q_len: int, quantized: bool = False):
    """Paged analogue of ``_verify_kernel``: linear layout (no eviction
    mask), per-request ``pos``, block-table gather in the k/v index_map."""
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref, kn_ref, vn_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, kn_ref, vn_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    ib, ij = pl.program_id(0), pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, page_size), 0)
    q_pos = pos + qi
    k_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (q_len, page_size), 1)
    valid = k_pos < pos                # committed rows only
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > q_pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (Q, D)
            _load_kv(k_ref, ks_ref),
            _load_kv(v_ref, vs_ref),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == n_blocks - 1)
    def _finish():
        _fold_candidates_and_finish(
            q_ref, kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref,
            scale=scale, window=window, logit_cap=logit_cap, q_len=q_len)


def _paged_verify_partials_kernel(bt_ref, pos_ref, *refs, scale: float,
                                  window: int, logit_cap: float,
                                  page_size: int, n_blocks: int, ppb: int,
                                  n_splits: int, q_len: int,
                                  quantized: bool = False):
    """Stage 1 of the two-stage paged verify sweep.  Same masks as
    ``_paged_verify_kernel``; candidates fold into the LAST split only."""
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref, kn_ref, vn_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, kn_ref, vn_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    ib = pl.program_id(0)
    isp, ij = pl.program_id(2), pl.program_id(3)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    gj = isp * ppb + ij
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, page_size), 0)
    q_pos = pos + qi
    k_pos = gj * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (q_len, page_size), 1)
    valid = (k_pos < pos) & (gj < n_blocks)      # committed rows only
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > q_pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (Q, D)
            _load_kv(k_ref, ks_ref),
            _load_kv(v_ref, vs_ref),
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when((ij == ppb - 1) & (isp == n_splits - 1))
    def _fold():
        _fold_candidates(q_ref, kn_ref, vn_ref, m_ref, l_ref, acc_ref,
                         scale=scale, window=window, logit_cap=logit_cap,
                         q_len=q_len)

    @pl.when(ij == ppb - 1)
    def _flush():
        _write_partials(part_ref, lse_ref, m_ref, l_ref, acc_ref)


def paged_verify_attention_pallas_partials(
    q: jax.Array,                  # (B, Q, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)    in-flight candidates
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) absolute position of q[:, 0]
    *,
    n_splits: int, window: int = 0, logit_cap: float = 0.0,
    scale: float | None = None, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Stage 1 only: per-split paged verify sweep, candidates folded into
    the last split.  Returns ``(partial (B, Hq, S, Q, Dv) fp32,
    lse (B, Hq, S, Q) fp32)``."""
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, Q, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    n_splits, ppb = _split_blocks(nb, n_splits)

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, Q, D)
    kt = k_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vt = v_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, Dv)
    knt = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, D)
    vnt = v_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, Dv)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _paged_verify_partials_kernel, scale=scale, window=window,
        logit_cap=logit_cap, page_size=ps, n_blocks=nb, ppb=ppb,
        n_splits=n_splits, q_len=Q, quantized=quantized)

    def kv_index(b, h, s, j, bt_ref, pos_ref, G=G, ppb=ppb, nb=nb):
        return (bt_ref[b, jnp.minimum(s * ppb + j, nb - 1)], h // G, 0, 0)

    in_specs = [pl.BlockSpec((1, 1, Q, D),
                             lambda b, h, s, j, bt_ref, pos_ref:
                             (b, h, 0, 0)),
                pl.BlockSpec((1, 1, ps, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (P, Hkv, ps, 1)
    in_specs.append(pl.BlockSpec((1, 1, ps, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))
    in_specs += [
        pl.BlockSpec((1, 1, Q, D),
                     lambda b, h, s, j, bt_ref, pos_ref, G=G:
                     (b, h // G, 0, 0)),
        pl.BlockSpec((1, 1, Q, Dv),
                     lambda b, h, s, j, bt_ref, pos_ref, G=G:
                     (b, h // G, 0, 0)),
    ]
    inputs += [knt, vnt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, Hq, n_splits, ppb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, Dv),
                         lambda b, h, s, j, bt_ref, pos_ref: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q),
                         lambda b, h, s, j, bt_ref, pos_ref: (b, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q,), jnp.float32),       # running max m, per query
            pltpu.VMEM((Q,), jnp.float32),       # running denom l
            pltpu.VMEM((Q, Dv), jnp.float32),    # running numerator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hq, n_splits, Q, Dv), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, n_splits, Q), jnp.float32)],
        interpret=interpret,
    )(bt, pos_arr, *inputs)


def paged_verify_attention_pallas(
    q: jax.Array,                  # (B, Q, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)    in-flight candidates
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) absolute position of q[:, 0]
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    n_splits: int = 1, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Split-K speculative verify attention over a paged KV cache: same
    block-table gather as ``paged_decode_attention_pallas``, ``q_len = K+1``
    query rows per (b, h) tile, in-flight candidates folded at the last
    grid step.  ``pos`` is per-request (ragged batch).  ``n_splits > 1``
    runs the two-stage pipeline; ``n_splits = 1`` is the original sweep.
    ``k_scale``/``v_scale`` flag an int8 pool (fused dequant in the block
    load); candidates are never quantized."""
    if n_splits > 1:
        partial, lse = paged_verify_attention_pallas_partials(
            q, k_pages, v_pages, k_new, v_new, block_tables, pos,
            n_splits=n_splits, window=window, logit_cap=logit_cap,
            scale=scale, k_scale=k_scale, v_scale=v_scale,
            interpret=interpret)
        out = merge_kv_splits_pallas(partial, lse, out_dtype=q.dtype,
                                     interpret=interpret)   # (B, Hq, Q, Dv)
        return out.transpose(0, 2, 1, 3)                    # (B, Q, Hq, Dv)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, Q, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, Q, D)
    kt = k_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vt = v_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, Dv)
    knt = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, D)
    vnt = v_new.transpose(0, 2, 1, 3)            # (B, Hkv, Q, Dv)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _paged_verify_kernel, scale=scale, window=window, logit_cap=logit_cap,
        page_size=ps, n_blocks=nb, q_len=Q, quantized=quantized)

    def kv_index(b, h, j, bt_ref, pos_ref, G=G):
        return (bt_ref[b, j], h // G, 0, 0)

    in_specs = [pl.BlockSpec((1, 1, Q, D),
                             lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, ps, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (P, Hkv, ps, 1)
    in_specs.append(pl.BlockSpec((1, 1, ps, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))
    in_specs += [
        pl.BlockSpec((1, 1, Q, D),
                     lambda b, h, j, bt_ref, pos_ref, G=G: (b, h // G, 0, 0)),
        pl.BlockSpec((1, 1, Q, Dv),
                     lambda b, h, j, bt_ref, pos_ref, G=G: (b, h // G, 0, 0)),
    ]
    inputs += [knt, vnt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, Hq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Q, Dv),
                               lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q,), jnp.float32),       # running max m, per query
            pltpu.VMEM((Q,), jnp.float32),       # running denom l
            pltpu.VMEM((Q, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Q, Dv), q.dtype),
        interpret=interpret,
    )(bt, pos_arr, *inputs)
    return out.transpose(0, 2, 1, 3)             # (B, Q, Hq, Dv)


def _paged_decode_kernel(bt_ref, pos_ref, *refs, scale: float, window: int,
                         logit_cap: float, page_size: int, n_blocks: int,
                         quantized: bool = False):
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    ib, ij = pl.program_id(0), pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # paged layout is *linear*: logical block j of request b holds absolute
    # positions [j*ps, (j+1)*ps) — no ring arithmetic, the block table alone
    # says where those positions live in the pool
    pos = pos_ref[ib]
    k_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = k_pos <= pos
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > pos - window)

    # blocks wholly beyond the request's length (or outside the window) are
    # predicated off — under partial occupancy most of the grid is this case
    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (1, D)
            _load_kv(k_ref, ks_ref),                         # (ps, D)
            _load_kv(v_ref, vs_ref),                         # (ps, Dv)
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_partials_kernel(bt_ref, pos_ref, *refs,
                                  scale: float, window: int, logit_cap: float,
                                  page_size: int, n_blocks: int, ppb: int,
                                  quantized: bool = False):
    """Stage 1 of the two-stage paged decode sweep: identical masks to
    ``_paged_decode_kernel``, but each split flushes normalized partials +
    LSE instead of chaining scratch across every page."""
    if quantized:
        (q_ref, k_ref, ks_ref, v_ref, vs_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref,
         part_ref, lse_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    ib = pl.program_id(0)
    isp, ij = pl.program_id(2), pl.program_id(3)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    gj = isp * ppb + ij
    k_pos = gj * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = (k_pos <= pos) & (gj < n_blocks)
    if window > 0:
        valid = jnp.logical_and(valid, k_pos > pos - window)

    @pl.when(jnp.any(valid))
    def _compute():
        _online_softmax_update(
            q_ref[0, 0].astype(jnp.float32),                 # (1, D)
            _load_kv(k_ref, ks_ref),                         # (ps, D)
            _load_kv(v_ref, vs_ref),                         # (ps, Dv)
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == ppb - 1)
    def _flush():
        _write_partials(part_ref, lse_ref, m_ref, l_ref, acc_ref)


def paged_decode_attention_pallas_partials(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    n_splits: int, window: int = 0, logit_cap: float = 0.0,
    scale: float | None = None, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Stage 1 only: per-split paged decode sweep.  Returns
    ``(partial (B, Hq, S, 1, Dv) fp32, lse (B, Hq, S, 1) fp32)``."""
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    n_splits, ppb = _split_blocks(nb, n_splits)

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vt = v_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, Dv)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _paged_decode_partials_kernel, scale=scale, window=window,
        logit_cap=logit_cap, page_size=ps, n_blocks=nb, ppb=ppb,
        quantized=quantized)

    def kv_index(b, h, s, j, bt_ref, pos_ref, G=G, ppb=ppb, nb=nb):
        return (bt_ref[b, jnp.minimum(s * ppb + j, nb - 1)], h // G, 0, 0)

    in_specs = [pl.BlockSpec((1, 1, 1, D),
                             lambda b, h, s, j, bt_ref, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, ps, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (P, Hkv, ps, 1)
    in_specs.append(pl.BlockSpec((1, 1, ps, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, Hq, n_splits, ppb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1, Dv),
                         lambda b, h, s, j, bt_ref, pos_ref: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1),
                         lambda b, h, s, j, bt_ref, pos_ref: (b, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running denom l
            pltpu.VMEM((1, Dv), jnp.float32),    # running numerator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hq, n_splits, 1, Dv), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, n_splits, 1), jnp.float32)],
        interpret=interpret,
    )(bt, pos_arr, *inputs)


def paged_decode_attention_pallas(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    n_splits: int = 1, interpret: bool = False,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Split-K decode attention over a paged KV cache.

    Same online-softmax accumulator discipline as the ring kernel, but the
    k/v ``index_map`` gathers through the scalar-prefetched block table:
    grid step ``(b, h, j)`` DMAs physical page ``block_tables[b, j]`` for kv
    head ``h // G``.  The pool is shared across requests — a request's pages
    need not be contiguous, only its table row must list them in logical
    order.  ``pos`` is per-request (ragged batch), so validity masks are
    per-row, unlike the ring kernel's single scalar.  ``n_splits > 1`` runs
    the two-stage pipeline; ``n_splits = 1`` is the original sweep.
    ``k_scale``/``v_scale`` flag an int8 pool: per-row fp32 scale blocks
    ride the same block-table gather and the dequant multiply is fused
    into the block load (int8 -> fp32 cast is free on the DMA'd tile)."""
    if n_splits > 1:
        partial, lse = paged_decode_attention_pallas_partials(
            q, k_pages, v_pages, block_tables, pos, n_splits=n_splits,
            window=window, logit_cap=logit_cap, scale=scale,
            k_scale=k_scale, v_scale=v_scale, interpret=interpret)
        out = merge_kv_splits_pallas(partial, lse, out_dtype=q.dtype,
                                     interpret=interpret)   # (B, Hq, 1, Dv)
        return out.transpose(0, 2, 1, 3)                    # (B, 1, Hq, Dv)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), \
        "k_scale and v_scale must be given together"
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vt = v_pages.transpose(0, 2, 1, 3)           # (P, Hkv, ps, Dv)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=window, logit_cap=logit_cap,
        page_size=ps, n_blocks=nb, quantized=quantized)

    def kv_index(b, h, j, bt_ref, pos_ref, G=G):
        return (bt_ref[b, j], h // G, 0, 0)

    in_specs = [pl.BlockSpec((1, 1, 1, D),
                             lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, ps, D), kv_index)]
    inputs = [qt, kt]
    if quantized:              # scale blocks ride the k/v index_map
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(k_scale.transpose(0, 2, 1, 3))     # (P, Hkv, ps, 1)
    in_specs.append(pl.BlockSpec((1, 1, ps, Dv), kv_index))
    inputs.append(vt)
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, ps, 1), kv_index))
        inputs.append(v_scale.transpose(0, 2, 1, 3))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, Hq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, Dv),
                               lambda b, h, j, bt_ref, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # running max m
            pltpu.VMEM((1,), jnp.float32),       # running denom l
            pltpu.VMEM((1, Dv), jnp.float32),    # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, Dv), q.dtype),
        interpret=interpret,
    )(bt, pos_arr, *inputs)
    return out.transpose(0, 2, 1, 3)             # (B, 1, Hq, Dv)


# --------------------------------------------------------------------------
# MLA compressed-latent paged decode — absorbed-matmul form
# --------------------------------------------------------------------------
#
# DeepSeek-style MLA caches ONE latent row per token — ``[c_kv | k_rope]``
# of width R = kv_lora_rank + rope_head_dim — shared by every q head
# (~5x fewer KV bytes than the GQA layout at DeepSeek-V2 shapes).  In the
# absorbed-matmul form the query is projected into latent space before the
# sweep (``q_abs = q_nope @ W_uk`` for the compressed block, raw ``q_rope``
# for the rope sub-block), so
#
#     q_abs . c_kv + q_rope . k_rope  =  [q_abs | q_rope] . [c_kv | k_rope]
#
# — one dot of the latent query against the full latent row — and the
# *value* read is the ``[:r_kv]`` slice of the SAME row.  One DMA per page
# therefore serves both k and v for all heads at once, which is why the
# grid here is (B, pages) with every q head in a single tile (the
# multi-row ``_online_softmax_update`` shape the verify kernels use, with
# q_len = Hq) instead of the GQA kernels' (B, Hq, pages): the occupancy
# unit is the page DMA, shared across 128 heads.  This is the aiter-style
# two-stage decomposition: stage-1 split-KV sweep over block-table pages
# emitting per-split ``(partial, lse)``, stage-2 the SAME
# ``merge_kv_splits_pallas`` LSE-merge every other sweep family uses.
# Validated against ``ref.mla_decode_split_ref`` / ``ref.mla_decode_paged_ref``.

def _mla_paged_decode_kernel(bt_ref, pos_ref, q_ref, lat_ref, o_ref,
                             m_ref, l_ref, acc_ref, *, scale: float,
                             logit_cap: float, page_size: int, n_blocks: int,
                             r_kv: int, n_heads: int):
    ib, ij = pl.program_id(0), pl.program_id(1)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    k_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (n_heads, page_size), 1)
    valid = k_pos <= pos

    @pl.when(jnp.any(valid))
    def _compute():
        lat = lat_ref[0].astype(jnp.float32)             # (ps, R) — one DMA
        _online_softmax_update(
            q_ref[0].astype(jnp.float32),                # (Hq, R)
            lat,                                         # k = full latent row
            lat[:, :r_kv],                               # v = its c_kv slice
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _mla_paged_decode_partials_kernel(bt_ref, pos_ref, q_ref, lat_ref,
                                      part_ref, lse_ref, m_ref, l_ref,
                                      acc_ref, *, scale: float,
                                      logit_cap: float, page_size: int,
                                      n_blocks: int, ppb: int, r_kv: int,
                                      n_heads: int):
    """Stage 1 of the two-stage MLA paged sweep: grid (B, n_splits, ppb),
    same masks as ``_mla_paged_decode_kernel``, each split flushing
    normalized per-head partials + LSE for the shared stage-2 merge."""
    ib, isp, ij = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    gj = isp * ppb + ij
    k_pos = gj * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (n_heads, page_size), 1)
    valid = (k_pos <= pos) & (gj < n_blocks)

    @pl.when(jnp.any(valid))
    def _compute():
        lat = lat_ref[0].astype(jnp.float32)             # (ps, R)
        _online_softmax_update(
            q_ref[0].astype(jnp.float32),                # (Hq, R)
            lat, lat[:, :r_kv],
            valid, m_ref, l_ref, acc_ref, scale=scale, logit_cap=logit_cap)

    @pl.when(ij == ppb - 1)
    def _flush():
        l = l_ref[...]
        part_ref[0, 0] = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        lse_ref[0, 0] = jnp.where(
            l > 0.0, m_ref[...] + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)


def mla_paged_decode_attention_pallas_partials(
    q_lat: jax.Array,              # (B, 1, Hq, R) latent queries [q_abs|q_rope]
    lat_pages: jax.Array,          # (P, ps, R)    latent page pool
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    r_kv: int, n_splits: int, scale: float, logit_cap: float = 0.0,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Stage 1 only: per-split MLA latent sweep.  Returns
    ``(partial (B, Hq, S, 1, r_kv) fp32, lse (B, Hq, S, 1) fp32)`` — the
    same partials layout as every other decode family, so the identical
    stage-2 merge applies.  ``scale`` is mandatory (MLA scales by the
    decompressed head dim, not R)."""
    B, _, Hq, R = q_lat.shape
    ps = lat_pages.shape[1]
    nb = block_tables.shape[1]
    n_splits, ppb = _split_blocks(nb, n_splits)

    qt = q_lat.reshape(B, Hq, R)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _mla_paged_decode_partials_kernel, scale=scale, logit_cap=logit_cap,
        page_size=ps, n_blocks=nb, ppb=ppb, r_kv=r_kv, n_heads=Hq)

    def lat_index(b, s, j, bt_ref, pos_ref, ppb=ppb, nb=nb):
        return (bt_ref[b, jnp.minimum(s * ppb + j, nb - 1)], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, n_splits, ppb),
        in_specs=[
            pl.BlockSpec((1, Hq, R), lambda b, s, j, bt_ref, pos_ref:
                         (b, 0, 0)),
            pl.BlockSpec((1, ps, R), lat_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Hq, r_kv),
                         lambda b, s, j, bt_ref, pos_ref: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, Hq),
                         lambda b, s, j, bt_ref, pos_ref: (b, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),      # running max m, per head
            pltpu.VMEM((Hq,), jnp.float32),      # running denom l
            pltpu.VMEM((Hq, r_kv), jnp.float32),  # running numerator
        ],
    )
    partial, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, n_splits, Hq, r_kv), jnp.float32),
            jax.ShapeDtypeStruct((B, n_splits, Hq), jnp.float32)],
        interpret=interpret,
    )(bt, pos_arr, qt, lat_pages)
    # -> the canonical (B, Hq, S, 1, Dv) partials layout shared by the
    # merge contract and the ref oracle
    return (partial.transpose(0, 2, 1, 3)[:, :, :, None, :],
            lse.transpose(0, 2, 1)[:, :, :, None])


def mla_paged_decode_attention_pallas(
    q_lat: jax.Array,              # (B, 1, Hq, R) latent queries [q_abs|q_rope]
    lat_pages: jax.Array,          # (P, ps, R)    latent page pool
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    r_kv: int, scale: float, logit_cap: float = 0.0, n_splits: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Compressed-latent MLA paged decode.  Returns latent outputs
    ``(B, 1, Hq, r_kv)`` (the W_uv / W_o expansion happens outside, per the
    absorbed form).  ``n_splits > 1`` runs the two-stage pipeline with the
    shared ``merge_kv_splits_pallas``; ``n_splits = 1`` is the single
    sequential sweep, bit-for-bit the stage-1-only result."""
    B, _, Hq, R = q_lat.shape
    ps = lat_pages.shape[1]
    nb = block_tables.shape[1]
    if n_splits > 1:
        partial, lse = mla_paged_decode_attention_pallas_partials(
            q_lat, lat_pages, block_tables, pos, r_kv=r_kv,
            n_splits=n_splits, scale=scale, logit_cap=logit_cap,
            interpret=interpret)
        out = merge_kv_splits_pallas(partial, lse, out_dtype=q_lat.dtype,
                                     interpret=interpret)  # (B, Hq, 1, r_kv)
        return out.transpose(0, 2, 1, 3)                   # (B, 1, Hq, r_kv)

    qt = q_lat.reshape(B, Hq, R)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(B)

    kernel = functools.partial(
        _mla_paged_decode_kernel, scale=scale, logit_cap=logit_cap,
        page_size=ps, n_blocks=nb, r_kv=r_kv, n_heads=Hq)

    def lat_index(b, j, bt_ref, pos_ref):
        return (bt_ref[b, j], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table + positions
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, Hq, R), lambda b, j, bt_ref, pos_ref: (b, 0, 0)),
            pl.BlockSpec((1, ps, R), lat_index),
        ],
        out_specs=pl.BlockSpec((1, Hq, r_kv),
                               lambda b, j, bt_ref, pos_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),      # running max m, per head
            pltpu.VMEM((Hq,), jnp.float32),      # running denom l
            pltpu.VMEM((Hq, r_kv), jnp.float32),  # running numerator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, r_kv), q_lat.dtype),
        interpret=interpret,
    )(bt, pos_arr, qt, lat_pages)
    return out[:, None]                          # (B, 1, Hq, r_kv)
