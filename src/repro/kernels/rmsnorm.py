"""Fused RMSNorm as a Pallas TPU kernel.

One pass over HBM: the (rows x d) input streams through VMEM in row-block
tiles, the fp32 mean-square reduction and the scale multiply fuse in
registers — XLA usually emits this as two kernels (reduce + scale) when the
scale is a separate parameter.  Supports the gemma (1 + w) parameterisation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float, gemma_style: bool):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    if gemma_style:
        w = 1.0 + w
    o_ref[...] = (y * w).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
                   gemma_style: bool = False, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        block_rows = 1                       # ragged smoke shapes
    kernel = functools.partial(_rms_kernel, eps=eps, gemma_style=gemma_style)
    out = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
                  pl.BlockSpec((d,), lambda r: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
