"""jit-ready kernel entry points with backend dispatch.

Every op has three interchangeable implementations:

  * ``ref``      — the pure-jnp oracle (kernels/ref.py), O(S^2) memory.
  * ``chunked``  — pure-jnp flash-style chunked algorithm.  This is the
                   DEFAULT: it is what the dry-run lowers (CPU stand-in
                   devices cannot lower Pallas TPU kernels) and it encodes
                   the same tiling the Pallas kernels use, so the roofline
                   derived from its HLO carries over.
  * ``pallas``   — the TPU kernel (kernels/flash_attention.py, ssd_scan.py,
                   rmsnorm.py, decode_attention.py), validated in interpret
                   mode on CPU.

Decode attention (the serving hot path) has its own backend axis on
``KernelPolicy`` (``decode``): ``jnp`` is the chunk-free CPU default,
``ref`` the whole-cache fp32 oracle, ``pallas`` the split-K TPU kernel.
The same axis drives both cache layouts — ``decode_attention`` (ring
buffer) and ``paged_decode_attention`` (block-table page pool, the
continuous-batching serving engine's layout) — and their multi-query
variants (``verify_attention`` / ``paged_verify_attention``: Q queries
share one cache sweep).  The multi-query paged sweep serves TWO callers
through one dispatch entry: speculative verify (Q = K+1 drafts + bonus)
and the prefix-sharing engine's *chunked paged prefill* (Q = suffix
chunk, scoring uncached prompt tokens against shared prefix pages — see
``transformer.prefill_suffix``); no separate prefill kernel exists or is
needed.

Models call these wrappers; the backend is chosen by ``KernelPolicy``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.ref import NEG_INF


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Which implementation backs each op."""
    attention: str = "auto"      # auto | ref | chunked | pallas | pallas_interpret
    ssd: str = "auto"
    rmsnorm: str = "auto"
    decode: str = "auto"         # auto | ref | jnp | pallas | pallas_interpret
    q_chunk: int = 1024
    k_chunk: int = 1024
    ssd_chunk: int = 128
    decode_k_chunk: int = 256    # split-K block for the Pallas decode kernel
    kv_splits: str | int = "auto"  # two-stage split count: "auto" | int (1 = single-stage)
    kv_dtype: str = "bfloat16"   # KV-pool storage: "bfloat16" | "int8" (per-row fp32 scales)


DEFAULT_POLICY = KernelPolicy()


# ==========================================================================
# Two-stage split-KV: occupancy heuristic + jnp partial/merge helpers
# ==========================================================================
def _sweep_executors() -> int:
    """How many independent executors can run decode-sweep grid cells
    concurrently.  On TPU the (b, h) cells map onto cores/devices; on the
    CPU stand-in, host threads."""
    if jax.default_backend() == "tpu":
        return jax.local_device_count()
    return os.cpu_count() or 1


def choose_kv_splits(batch: int, kv_len: int, q_heads: int,
                     n_cores: int | None = None, *,
                     block: int = 256, max_splits: int = 16) -> int:
    """Occupancy-model heuristic for the two-stage split-KV sweep.

    The stage-1 grid has ``batch * q_heads * num_kv_splits`` independent
    cells.  When ``batch * q_heads`` already oversubscribes the executors
    (the common high-batch serving case), splitting only adds stage-2 merge
    traffic — return 1, which is bit-for-bit today's single-stage sweep.
    Only when the natural grid *underfills* the machine (deep cache, low
    batch — exactly the power-capped latency-bound regime) do we split,
    just enough to cover the executors (2x for load balance), never past
    the number of k-blocks or ``max_splits`` (merge cost grows with S).
    """
    if n_cores is None:
        n_cores = _sweep_executors()
    cells = int(batch) * int(q_heads)
    n_blocks = -(-int(kv_len) // max(1, int(block)))
    if cells >= 2 * n_cores or n_blocks <= 1:
        return 1
    return max(1, min(-(-2 * n_cores // max(1, cells)), n_blocks, max_splits))


def effective_kv_len(kv_len: int, window: int = 0) -> int:
    """Clip the logical KV length to the attention window for occupancy
    decisions.  A windowed layer never attends past ``window`` keys no
    matter how deep the logical position is, so the split heuristic must
    see ``min(kv_len, window)`` — a 32k-position sliding-window cache is a
    SHALLOW sweep, and splitting it only adds merge traffic."""
    kv_len = int(kv_len)
    return min(kv_len, int(window)) if window > 0 else kv_len


def _resolve_kv_splits(policy: KernelPolicy, batch: int, kv_len: int,
                       q_heads: int, *, block: int) -> int:
    if policy.kv_splits == "auto":
        return choose_kv_splits(batch, kv_len, q_heads, block=block)
    return max(1, int(policy.kv_splits))


def _lse_merge_jnp(partial: jax.Array, lse: jax.Array) -> jax.Array:
    """Online-softmax merge over the split axis: ``partial (..., S, Dv)``
    + ``lse (..., S)`` -> ``(..., Dv)``.  Exact: each split's normalized
    partial re-weighted by ``exp(lse_s - max_s lse)`` reconstructs the
    unsplit numerator/denominator pair."""
    m = jnp.max(lse, axis=-1, keepdims=True)
    w = jnp.exp(lse - m)                               # (..., S)
    den = jnp.maximum(jnp.sum(w, axis=-1), 1e-30)
    acc = jnp.sum(partial * w[..., None], axis=-2)     # (..., Dv)
    return acc / den[..., None]


def _split_attend_jnp(s: jax.Array, vf: jax.Array, n_splits: int) -> jax.Array:
    """Two-stage softmax-weighted sum for the jnp backend: partition the
    masked score axis into ``n_splits`` slices, emit per-split normalized
    partials + LSE, then LSE-merge.  Mirrors the Pallas partial contract
    (ragged last split padded with masked scores).

    s:  (B, Hkv, G, R, K) fp32 masked scores (invalid entries = NEG_INF)
    vf: (B, K, Hkv, Dv)   values in logical key order
    -> (B, Hkv, G, R, Dv) fp32
    """
    B, Hkv, G, R, K = s.shape
    Dv = vf.shape[-1]
    S = max(1, min(int(n_splits), K))
    kps = -(-K // S)
    pad = S * kps - K
    sp = jnp.pad(s, [(0, 0)] * 4 + [(0, pad)], constant_values=NEG_INF)
    vp = jnp.pad(vf, [(0, 0), (0, pad), (0, 0), (0, 0)])
    sp = sp.reshape(B, Hkv, G, R, S, kps)
    vp = vp.reshape(B, S, kps, Hkv, Dv)
    m = jnp.max(sp, axis=-1)                           # (B,h,g,R,S)
    p = jnp.exp(sp - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgrsk,bskhd->bhgrsd", p, vp,
                     preferred_element_type=jnp.float32)
    partial = acc / jnp.maximum(l, 1e-30)[..., None]
    # fully-masked splits: every score is NEG_INF, so p = exp(0) = 1 and l
    # counts the padding — the m-guard (not l > 0) is what zeroes their
    # merge weight; the raw-l denominator above keeps partial finite.
    lse = jnp.where(m > 0.5 * NEG_INF,
                    m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
    return _lse_merge_jnp(partial, lse)


_KPOS_FALLBACK_WARNED: set[str] = set()


def _warn_k_pos_fallback(entry: str) -> None:
    """One-time (per entry point) warning when a custom ``k_pos`` silently
    costs the caller the Pallas fast path."""
    if entry in _KPOS_FALLBACK_WARNED:
        return
    _KPOS_FALLBACK_WARNED.add(entry)
    warnings.warn(
        f"{entry}: custom k_pos slot layout disables the Pallas decode "
        "kernel (it derives ring positions from pos, assuming the canonical "
        "slot = p % C layout); falling back to the jnp backend for this "
        "call", RuntimeWarning, stacklevel=3)


_KV_DTYPE_FALLBACK_WARNED: set[str] = set()


def warn_kv_dtype_fallback(family: str, reason: str) -> None:
    """One-time (per model family) warning when ``kv_dtype=int8`` was
    requested but the family's verify/commit path cannot run quantized and
    silently falls back to the unquantized pools."""
    if family in _KV_DTYPE_FALLBACK_WARNED:
        return
    _KV_DTYPE_FALLBACK_WARNED.add(family)
    warnings.warn(
        f"kv_dtype=int8 requested for model family {family!r} but {reason}; "
        "falling back to unquantized (bfloat16) KV pools for this engine",
        RuntimeWarning, stacklevel=3)


_PAGED_FALLBACK_WARNED: set[str] = set()


def warn_paged_fallback(name: str, feature: str) -> None:
    """One-time (per config) warning when a model family cannot ride the
    paged serving engine and silently falls back to the ring-cache loop,
    naming the SPECIFIC blocking feature (mirrors
    ``warn_kv_dtype_fallback``)."""
    if name in _PAGED_FALLBACK_WARNED:
        return
    _PAGED_FALLBACK_WARNED.add(name)
    warnings.warn(
        f"config {name!r} falls back to the ring-cache serving loop: paged "
        f"serving blocked by {feature}", RuntimeWarning, stacklevel=3)


# ==========================================================================
# Attention
# ==========================================================================
def _chunk_attend(q, k, v, carry, mask, scale, logit_cap):
    """One (q-chunk, k-chunk) online-softmax update.  All fp32.

    q: (B,Hkv,G,Cq,D)  k: (B,Hkv,Ck,D)  v: (B,Hkv,Ck,Dv)
    carry = (m, l, acc): ((B,Hkv,G,Cq), (B,Hkv,G,Cq), (B,Hkv,G,Cq,Dv))
    mask: (Cq, Ck) bool or None.
    """
    m, l, acc = carry
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhgqk,bhkd->bhgqd", p, v, preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def flash_attention_jnp(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0, logit_cap: float = 0.0,
    scale: float | None = None, q_offset: int = 0,
    q_chunk: int = 1024, k_chunk: int = 1024,
) -> jax.Array:
    """Flash-style chunked attention, pure jnp.

    Memory is O(Cq*Ck) instead of O(Sq*Sk).  Causal/window structure is
    exploited *structurally*: k-chunks entirely outside [q_lo - window,
    q_hi] are never computed, so HLO FLOPs reflect the real triangle —
    this is what makes the roofline compute term honest for prefill_32k.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    if Sq % q_chunk or Sk % k_chunk:
        # fall back for ragged shapes (smoke tests)
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  logit_cap=logit_cap, scale=scale,
                                  q_offset=q_offset)

    # keep q/k/v in their storage dtype; the per-chunk einsums accumulate in
    # fp32 via preferred_element_type (pre-casting everything to fp32 would
    # triple the HBM residency of the whole tensor — measured 2.4 GB/layer
    # extra on deepseek-v2 prefill)
    qf = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    kf = k.transpose(0, 2, 1, 3)                         # (B,Hkv,Sk,D)
    vf = v.transpose(0, 2, 1, 3)                         # (B,Hkv,Sk,Dv)

    n_q = Sq // q_chunk
    outs = []
    for i in range(n_q):                                  # static python loop
        q_lo = q_offset + i * q_chunk
        q_hi = q_lo + q_chunk - 1
        # visible k range for this q chunk
        k_hi = min(Sk, q_hi + 1) if causal else Sk
        k_lo = max(0, q_lo - window + 1) if window > 0 else 0
        j_lo, j_hi = k_lo // k_chunk, -(-k_hi // k_chunk)  # ceil
        j_lo = min(j_lo, j_hi - 1)
        qi = qf[:, :, :, i * q_chunk:(i + 1) * q_chunk]   # (B,Hkv,G,Cq,D)

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32)

        q_pos = q_lo + jnp.arange(q_chunk)[:, None]

        def body(carry, xs, q_pos=q_pos):
            kj, vj, j = xs
            k_pos = j * k_chunk + jnp.arange(k_chunk)[None, :]
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= k_pos <= q_pos
            if window > 0:
                mask &= k_pos > q_pos - window
            return _chunk_attend(qi, kj, vj, carry, mask, scale, logit_cap), None

        nj = j_hi - j_lo
        ks = kf[:, :, j_lo * k_chunk:j_hi * k_chunk].reshape(B, Hkv, nj, k_chunk, D)
        vs = vf[:, :, j_lo * k_chunk:j_hi * k_chunk].reshape(B, Hkv, nj, k_chunk, Dv)
        xs = (jnp.moveaxis(ks, 2, 0), jnp.moveaxis(vs, 2, 0),
              jnp.arange(j_lo, j_hi))
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))

    o = jnp.concatenate(outs, axis=3)                     # (B,Hkv,G,Sq,Dv)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv).astype(q.dtype)


def decode_attention_jnp(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_pos: jax.Array,              # (C,) or (B, C) slot positions (-1 invalid)
    pos: jax.Array,                # () or (B,) current absolute position of q
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    n_splits: int = 1,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32 per-row scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-token decode against a (ring-buffer) KV cache.  ``k_pos`` /
    ``pos`` may carry a leading batch axis: ragged batches of private ring
    buffers (each slot of the paged engine at its own depth) mask per-row.

    The cache stays in its storage dtype end to end; the two einsums
    accumulate in fp32 via ``preferred_element_type`` (same rationale as
    ``flash_attention_jnp``: decode streams the WHOLE cache per token, so a
    whole-cache fp32 pre-cast would triple the hot path's HBM traffic).
    ``n_splits > 1`` runs the two-stage partial/merge path (exact; mirrors
    the Pallas split contract); 1 is the plain softmax.  When ``k_scale`` /
    ``v_scale`` are given the cache is int8 and is dequantized (cast * scale,
    fp32) before the einsums — the jnp mirror of the fused-dequant block
    load in the Pallas sweep."""
    B, _, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale
    if v_scale is not None:
        v_cache = v_cache.astype(jnp.float32) * v_scale
    qf = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = s.astype(jnp.float32)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_posb = jnp.asarray(k_pos).reshape(-1, C)           # (1, C) or (B, C)
    posb = jnp.asarray(pos).reshape(-1)[:, None]         # (1, 1) or (B, 1)
    valid = (k_posb >= 0) & (k_posb <= posb)
    if window > 0:
        valid &= k_posb > posb - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    if n_splits > 1:
        o = _split_attend_jnp(s[:, :, :, None, :], v_cache, n_splits)[..., 0, :]
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, v_cache.shape[-1]).astype(q.dtype)


def verify_attention_jnp(
    q: jax.Array,                  # (B, Q, Hq, D)   Q = K+1 fed tokens
    k_cache: jax.Array,            # (B, C, Hkv, D)  committed through pos-1
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)  in-flight candidate rows
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    k_pos: jax.Array,              # (C,) absolute position per slot (<0 invalid)
    pos: jax.Array,                # () absolute position of q[:, 0]
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    n_splits: int = 1,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32 per-row scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Speculative multi-query decode (verify) against a ring-buffer cache.

    Query i (absolute position ``pos + i``) attends to the committed cache
    plus candidates ``j <= i`` of the in-flight block; candidate k/v never
    touch the cache so a rejected suffix needs no rollback.  Ring-eviction
    semantics are preserved (``k_pos > q_pos - C``): entries the sequential
    loop would already have overwritten are masked.  Storage dtype is kept
    end to end; einsums accumulate in fp32 (same discipline as
    ``decode_attention_jnp`` — one cache sweep amortised over K+1 queries
    is the whole J/token win).  With ``k_scale``/``v_scale`` the cache is
    int8 and dequantized before use; the in-flight candidates are always
    unquantized (they are transient activations, never pool rows)."""
    B, Q, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale
    if v_scale is not None:
        v_cache = v_cache.astype(jnp.float32) * v_scale
    qf = q.reshape(B, Q, Hkv, G, D)
    q_pos = pos + jnp.arange(Q)[:, None]                     # (Q, 1)

    s_c = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_cache,
                     preferred_element_type=jnp.float32).astype(jnp.float32)
    s_n = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_new,
                     preferred_element_type=jnp.float32).astype(jnp.float32)
    s = jnp.concatenate([s_c, s_n], axis=-1) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    valid_c = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos) \
        & (k_pos[None, :] > q_pos - C)
    n_pos = pos + jnp.arange(Q)[None, :]
    valid_n = n_pos <= q_pos
    if window > 0:
        valid_c &= k_pos[None, :] > q_pos - window
        valid_n &= n_pos > q_pos - window
    valid = jnp.concatenate([valid_c, valid_n], axis=-1)     # (Q, C+Q)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    vf = jnp.concatenate([v_cache, v_new], axis=1)
    if n_splits > 1:
        o = _split_attend_jnp(s, vf, n_splits)               # (B,h,g,Q,Dv)
        o = o.transpose(0, 3, 1, 2, 4)                       # (B,Q,h,g,Dv)
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, Q, Hq, Dv).astype(q.dtype)


def paged_verify_attention_jnp(
    q: jax.Array,                  # (B, Q, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)    in-flight candidates
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) absolute position of q[:, 0]
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    n_splits: int = 1,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32 per-row scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged analogue of ``verify_attention_jnp``: the pool is committed
    through ``pos[b] - 1`` (linear layout, no eviction); ``pos`` is
    per-request so validity is per-row.  With ``k_scale``/``v_scale`` the
    pool is int8: scale rows are gathered through the same block tables and
    the gathered cache is dequantized before the einsums."""
    B, Q, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    Dv = v_pages.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kg = k_pages[block_tables].reshape(B, nb * ps, Hkv, D)
    vg = v_pages[block_tables].reshape(B, nb * ps, Hkv, Dv)
    if k_scale is not None:
        kg = kg.astype(jnp.float32) \
            * k_scale[block_tables].reshape(B, nb * ps, Hkv, 1)
    if v_scale is not None:
        vg = vg.astype(jnp.float32) \
            * v_scale[block_tables].reshape(B, nb * ps, Hkv, 1)
    qf = q.reshape(B, Q, Hkv, G, D)
    q_pos = pos.reshape(B, 1, 1) + jnp.arange(Q)[None, :, None]  # (B, Q, 1)

    s_c = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kg,
                     preferred_element_type=jnp.float32).astype(jnp.float32)
    s_n = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_new,
                     preferred_element_type=jnp.float32).astype(jnp.float32)
    s = jnp.concatenate([s_c, s_n], axis=-1) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    k_pos = jnp.arange(nb * ps)[None, None, :]
    valid_c = jnp.broadcast_to(k_pos < pos.reshape(B, 1, 1), (B, Q, nb * ps))
    n_pos = pos.reshape(B, 1, 1) + jnp.arange(Q)[None, None, :]
    valid_n = n_pos <= q_pos
    if window > 0:
        valid_c = valid_c & (k_pos > q_pos - window)
        valid_n &= n_pos > q_pos - window
    valid = jnp.concatenate([valid_c, valid_n], axis=-1)     # (B, Q, K+Q)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    vf = jnp.concatenate([vg, v_new], axis=1)
    if n_splits > 1:
        o = _split_attend_jnp(s, vf, n_splits)               # (B,h,g,Q,Dv)
        o = o.transpose(0, 3, 1, 2, 4)                       # (B,Q,h,g,Dv)
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, Q, Hq, Dv).astype(q.dtype)


def paged_decode_attention_jnp(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    n_splits: int = 1,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32 per-row scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-token decode against a paged KV cache, pure jnp.

    Gathers each request's pages into logical order (block j holds positions
    [j*ps, (j+1)*ps)) and keeps the pool in its storage dtype — the einsums
    accumulate in fp32 via ``preferred_element_type``, same discipline as
    ``decode_attention_jnp``.  ``pos`` is per-request: the batch is ragged,
    so validity is a (B, K) mask rather than the ring path's shared (C,).
    With ``k_scale``/``v_scale`` the pool is int8: scale rows are gathered
    through the same block tables and dequantized before the einsums."""
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    Dv = v_pages.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kg = k_pages[block_tables].reshape(B, nb * ps, Hkv, D)
    vg = v_pages[block_tables].reshape(B, nb * ps, Hkv, Dv)
    if k_scale is not None:
        kg = kg.astype(jnp.float32) \
            * k_scale[block_tables].reshape(B, nb * ps, Hkv, 1)
    if v_scale is not None:
        vg = vg.astype(jnp.float32) \
            * v_scale[block_tables].reshape(B, nb * ps, Hkv, 1)
    qf = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kg,
                   preferred_element_type=jnp.float32) * scale
    s = s.astype(jnp.float32)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(nb * ps)[None, :]
    posb = jnp.asarray(pos).reshape(B, 1)
    valid = k_pos <= posb
    if window > 0:
        valid &= k_pos > posb - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    if n_splits > 1:
        o = _split_attend_jnp(s[:, :, :, None, :], vg, n_splits)[..., 0, :]
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p, vg,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, Dv).astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Backend-dispatching paged decode attention (continuous-batching hot
    path).  Shares the ``decode`` backend axis with the ring entry point:
    ``auto`` resolves to the block-table-gather Pallas kernel on TPU and the
    gather-then-attend jnp path elsewhere.  The split-K block is the page
    size — pages are the DMA unit, so ``decode_k_chunk`` does not apply.
    ``k_scale``/``v_scale`` (per-row fp32, int8 pools) flow to every backend:
    the Pallas kernel fuses the dequant into the stage-1 block load."""
    backend = policy.decode
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    ps, nb = k_pages.shape[1], block_tables.shape[1]
    n_splits = _resolve_kv_splits(policy, q.shape[0],
                                  effective_kv_len(nb * ps, window),
                                  q.shape[2], block=ps)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.paged_decode_attention_pallas(
            q, k_pages, v_pages, block_tables, pos, window=window,
            logit_cap=logit_cap, scale=scale, n_splits=n_splits,
            k_scale=k_scale, v_scale=v_scale,
            interpret=backend == "pallas_interpret")
    if backend == "ref":
        return _ref.paged_decode_attention_ref(
            q, k_pages, v_pages, block_tables, pos, window=window,
            logit_cap=logit_cap, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    if backend == "jnp":
        return paged_decode_attention_jnp(
            q, k_pages, v_pages, block_tables, pos, window=window,
            logit_cap=logit_cap, scale=scale, n_splits=n_splits,
            k_scale=k_scale, v_scale=v_scale)
    raise ValueError(f"unknown decode backend {backend!r}")


# ==========================================================================
# MLA compressed-latent decode (absorbed-matmul form)
# ==========================================================================
def mla_absorbed_attend_jnp(
    q_abs: jax.Array,              # (B, H, r_kv)  q_nope absorbed through W_uk
    q_rope: jax.Array,             # (B, H, dr)    rope sub-block queries
    c_kv: jax.Array,               # (B, C, r_kv)  compressed latents (k AND v)
    k_rope: jax.Array,             # (B, C, dr)    shared rope keys
    valid: jax.Array,              # (B, C) bool
    *, scale: float, logit_cap: float = 0.0, n_splits: int = 1,
) -> jax.Array:
    """The absorbed-matmul MLA attend shared by the ring ``mla_decode`` and
    the paged jnp path — one latent row per token attended by every head
    (Hkv = 1, G = H), value = the compressed latent itself.  Keeping both
    cache layouts on this one body is what makes the paged engine's greedy
    streams match the ring reference.  Scores and the value reduction
    accumulate in fp32; ``n_splits > 1`` runs the exact two-stage
    partial/LSE-merge path (mirrors the Pallas split contract).
    Returns latent outputs ``(B, H, r_kv)`` in the query dtype."""
    s = jnp.einsum("bhr,bcr->bhc", q_abs, c_kv,
                   preferred_element_type=jnp.float32) \
        + jnp.einsum("bhk,bck->bhc", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    s = (s * scale).astype(jnp.float32)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    if n_splits > 1:
        o = _split_attend_jnp(s[:, None, :, None, :],
                              c_kv[:, :, None, :], n_splits)[:, 0, :, 0, :]
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhc,bcr->bhr", p, c_kv,
                       preferred_element_type=jnp.float32)
    return o.astype(q_abs.dtype)


def mla_decode_paged_jnp(
    q_lat: jax.Array,              # (B, 1, Hq, R) latent queries [q_abs|q_rope]
    lat_pages: jax.Array,          # (P, ps, R)    latent page pool
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *, r_kv: int, scale: float, logit_cap: float = 0.0, n_splits: int = 1,
) -> jax.Array:
    """Paged MLA decode, pure jnp: gather the latent pages into logical
    order, then the shared absorbed attend.  Linear layout — validity is
    simply ``k_pos <= pos[b]``."""
    B, _, Hq, R = q_lat.shape
    ps = lat_pages.shape[1]
    nb = block_tables.shape[1]
    latg = lat_pages[block_tables].reshape(B, nb * ps, R)
    valid = jnp.arange(nb * ps)[None, :] <= jnp.asarray(pos).reshape(B, 1)
    o = mla_absorbed_attend_jnp(
        q_lat[:, 0, :, :r_kv], q_lat[:, 0, :, r_kv:],
        latg[..., :r_kv], latg[..., r_kv:], valid,
        scale=scale, logit_cap=logit_cap, n_splits=n_splits)
    return o[:, None]                              # (B, 1, Hq, r_kv)


def mla_decode_paged(
    q_lat: jax.Array,              # (B, 1, Hq, R) latent queries [q_abs|q_rope]
    lat_pages: jax.Array,          # (P, ps, R)    latent page pool
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *,
    r_kv: int, scale: float, logit_cap: float = 0.0,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Backend-dispatching compressed-latent MLA paged decode — the model
    zoo's headline sweep.  Shares the ``decode`` backend axis: ``auto``
    resolves to the latent-pool Pallas kernel on TPU and the
    gather-then-attend jnp path elsewhere.  The split count comes from the
    same occupancy heuristic at the MLA grid shape: every q head shares the
    ONE latent row, so the natural grid has ``batch * 1`` cells (the page
    DMA is shared across heads), i.e. ``q_heads = 1`` — MLA decode at low
    batch is the deepest occupancy deficit in the zoo, exactly where
    splitting pays.  Returns latent outputs ``(B, 1, Hq, r_kv)``; the
    ``W_uv`` / ``W_o`` expansion happens in the caller (absorbed form)."""
    backend = policy.decode
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    ps, nb = lat_pages.shape[1], block_tables.shape[1]
    # q_heads = 1: the MLA kernel tiles ALL heads per page DMA (grid is
    # (B, splits, pages), not (B, Hq, splits, pages))
    n_splits = _resolve_kv_splits(policy, q_lat.shape[0], nb * ps, 1,
                                  block=ps)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.mla_paged_decode_attention_pallas(
            q_lat, lat_pages, block_tables, pos, r_kv=r_kv, scale=scale,
            logit_cap=logit_cap, n_splits=n_splits,
            interpret=backend == "pallas_interpret")
    if backend == "ref":
        return _ref.mla_decode_paged_ref(
            q_lat, lat_pages, block_tables, pos, r_kv=r_kv, scale=scale,
            logit_cap=logit_cap)
    if backend == "jnp":
        return mla_decode_paged_jnp(
            q_lat, lat_pages, block_tables, pos, r_kv=r_kv, scale=scale,
            logit_cap=logit_cap, n_splits=n_splits)
    raise ValueError(f"unknown decode backend {backend!r}")


def ring_positions(pos: jax.Array, cache_len: int) -> jax.Array:
    """Absolute position held by each ring-buffer slot under the canonical
    layout (slot = p % C): ``pos - ((pos - s) mod C)``.  Slots not yet
    written resolve to negative positions (masked as invalid everywhere).
    ``pos`` may be scalar -> (C,), or (B,) -> (B, C) for ragged batches of
    private ring buffers (the paged engine's windowed layers)."""
    s = jnp.arange(cache_len)
    p = jnp.asarray(pos)
    if p.ndim == 0:
        return p - jnp.mod(p - s, cache_len)
    return p[:, None] - jnp.mod(p[:, None] - s, cache_len)


def decode_attention(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)   ring buffer
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    pos: jax.Array,                # () current absolute position of q
    *,
    k_pos: jax.Array | None = None,   # (C,) slot positions; None -> canonical ring
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Backend-dispatching decode-attention entry point (serving hot path).

    ``auto`` resolves to the split-K Pallas kernel on TPU and the chunk-free
    jnp path elsewhere (CPU stand-ins cannot lower Pallas TPU kernels).  The
    Pallas path derives slot positions from ``pos`` inside the kernel and
    therefore requires the canonical ring layout — callers passing a custom
    ``k_pos`` are routed to the jnp path instead.  ``k_scale``/``v_scale``
    (per-row fp32, int8 caches) flow to every backend; the Pallas kernel
    fuses the dequant into the stage-1 block load.
    """
    backend = policy.decode
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend in ("pallas", "pallas_interpret") and k_pos is not None:
        _warn_k_pos_fallback("decode_attention")
        backend = "jnp"            # custom slot layout: ring derivation invalid
    n_splits = _resolve_kv_splits(policy, q.shape[0],
                                  effective_kv_len(k_cache.shape[1], window),
                                  q.shape[2], block=policy.decode_k_chunk)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention_pallas(
            q, k_cache, v_cache, pos, window=window, logit_cap=logit_cap,
            scale=scale, block_k=policy.decode_k_chunk, n_splits=n_splits,
            k_scale=k_scale, v_scale=v_scale,
            interpret=backend == "pallas_interpret")
    if k_pos is None:
        k_pos = ring_positions(pos, k_cache.shape[1])
    if backend == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, k_pos, pos,
                                         window=window, logit_cap=logit_cap,
                                         scale=scale,
                                         k_scale=k_scale, v_scale=v_scale)
    if backend == "jnp":
        return decode_attention_jnp(q, k_cache, v_cache, k_pos, pos,
                                    window=window, logit_cap=logit_cap,
                                    scale=scale, n_splits=n_splits,
                                    k_scale=k_scale, v_scale=v_scale)
    raise ValueError(f"unknown decode backend {backend!r}")


def verify_attention(
    q: jax.Array,                  # (B, Q, Hq, D)   Q = K+1 fed tokens
    k_cache: jax.Array,            # (B, C, Hkv, D)  ring, committed thru pos-1
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)  in-flight candidate rows
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    pos: jax.Array,                # () absolute position of q[:, 0]
    *,
    k_pos: jax.Array | None = None,   # (C,) slot positions; None -> canonical ring
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Backend-dispatching speculative verify attention (ring layout).

    Scores ``Q = K+1`` queries at positions ``pos .. pos+K`` in ONE cache
    sweep — the decode hot path's bytes-per-token lever: the whole KV cache
    streams HBM once for K+1 candidate tokens instead of once per token.
    Shares the ``decode`` backend axis; the candidates' k/v ride along as a
    separate in-flight block so rejection never needs a cache rollback.
    ``k_scale``/``v_scale`` dequantize an int8 cache (candidates always stay
    unquantized)."""
    backend = policy.decode
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend in ("pallas", "pallas_interpret") and k_pos is not None:
        _warn_k_pos_fallback("verify_attention")
        backend = "jnp"            # custom slot layout: ring derivation invalid
    n_splits = _resolve_kv_splits(policy, q.shape[0],
                                  effective_kv_len(k_cache.shape[1], window),
                                  q.shape[2], block=policy.decode_k_chunk)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.verify_attention_pallas(
            q, k_cache, v_cache, k_new, v_new, pos, window=window,
            logit_cap=logit_cap, scale=scale, block_k=policy.decode_k_chunk,
            n_splits=n_splits, k_scale=k_scale, v_scale=v_scale,
            interpret=backend == "pallas_interpret")
    if k_pos is None:
        # committed prefix ends at pos - 1: that is the ring reference
        k_pos = ring_positions(pos - 1, k_cache.shape[1])
    if backend == "ref":
        return _ref.verify_attention_ref(
            q, k_cache, v_cache, k_new, v_new, k_pos, pos, window=window,
            logit_cap=logit_cap, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    if backend == "jnp":
        return verify_attention_jnp(
            q, k_cache, v_cache, k_new, v_new, k_pos, pos, window=window,
            logit_cap=logit_cap, scale=scale, n_splits=n_splits,
            k_scale=k_scale, v_scale=v_scale)
    raise ValueError(f"unknown decode backend {backend!r}")


def paged_verify_attention(
    q: jax.Array,                  # (B, Q, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)    in-flight candidates
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) absolute position of q[:, 0]
    *,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Backend-dispatching multi-query attention over the paged KV cache
    (the continuous-batching engine's layout).  ``pos`` is per-request —
    every slot scores its own Q in-flight tokens at its own depth.  Two
    callers share this entry: speculative verify (Q = K+1 candidates) and
    chunked paged prefill (Q = prompt-suffix chunk against a shared cached
    prefix; the commit side differs, the sweep is identical).
    ``k_scale``/``v_scale`` dequantize an int8 pool (candidates always stay
    unquantized)."""
    backend = policy.decode
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    ps, nb = k_pages.shape[1], block_tables.shape[1]
    n_splits = _resolve_kv_splits(policy, q.shape[0],
                                  effective_kv_len(nb * ps, window),
                                  q.shape[2], block=ps)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.paged_verify_attention_pallas(
            q, k_pages, v_pages, k_new, v_new, block_tables, pos,
            window=window, logit_cap=logit_cap, scale=scale,
            n_splits=n_splits, k_scale=k_scale, v_scale=v_scale,
            interpret=backend == "pallas_interpret")
    if backend == "ref":
        return _ref.paged_verify_attention_ref(
            q, k_pages, v_pages, k_new, v_new, block_tables, pos,
            window=window, logit_cap=logit_cap, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    if backend == "jnp":
        return paged_verify_attention_jnp(
            q, k_pages, v_pages, k_new, v_new, block_tables, pos,
            window=window, logit_cap=logit_cap, scale=scale,
            n_splits=n_splits, k_scale=k_scale, v_scale=v_scale)
    raise ValueError(f"unknown decode backend {backend!r}")


def attention(q, k, v, *, causal=True, window=0, logit_cap=0.0, scale=None,
              q_offset=0, policy: KernelPolicy = DEFAULT_POLICY) -> jax.Array:
    """Backend-dispatching attention entry point (training / prefill)."""
    backend = policy.attention
    if backend == "auto":
        backend = "ref" if q.shape[1] * k.shape[1] <= 512 * 512 else "chunked"
    if backend == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  logit_cap=logit_cap, scale=scale,
                                  q_offset=q_offset)
    if backend == "chunked":
        return flash_attention_jnp(q, k, v, causal=causal, window=window,
                                   logit_cap=logit_cap, scale=scale,
                                   q_offset=q_offset, q_chunk=policy.q_chunk,
                                   k_chunk=policy.k_chunk)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  logit_cap=logit_cap, scale=scale,
                                  q_offset=q_offset,
                                  interpret=backend == "pallas_interpret")
    raise ValueError(f"unknown attention backend {backend!r}")


# ==========================================================================
# Mamba2 SSD
# ==========================================================================
def _segsum(a: jax.Array) -> jax.Array:
    """L[t, s] = sum_{r=s+1..t} a[r] for s <= t else -inf.  a: (..., Q)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, L, -jnp.inf)


def ssd_chunked_jnp(
    x: jax.Array, dt: jax.Array, A: jax.Array,
    B_mat: jax.Array, C_mat: jax.Array, D: jax.Array | None = None, *,
    chunk: int = 128, initial_state: jax.Array | None = None,
    return_state: bool = False,
):
    """Chunked SSD (state-space duality) — Mamba2 Algorithm 1, pure jnp.

    Intra-chunk terms use the quadratic (attention-like) form on Q x Q
    blocks; inter-chunk state is carried by a scan over chunks.  Matches
    ``ref.ssd_ref`` to fp32 tolerance and is what the Pallas kernel tiles.
    """
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    if S % chunk:
        return _ref.ssd_ref(x, dt, A, B_mat, C_mat, D,
                            initial_state=initial_state,
                            return_state=return_state)
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(Bb, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, chunk, H)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B_mat.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, chunk, H, N)
    Cf = jnp.repeat(C_mat.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, chunk, H, N)

    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp                      # (B,Q,H,*) for this chunk
        a = dtc * Af                               # (B,Q,H)
        a_t = a.transpose(0, 2, 1)                 # (B,H,Q)
        cs = jnp.cumsum(a_t, axis=-1)              # (B,H,Q)
        # 1. intra-chunk (diagonal block), attention-like
        L = jnp.exp(_segsum(a_t))                  # (B,H,Q,Q), lower-tri
        Gmat = jnp.einsum("bqhn,bshn->bhqs", Cc, Bc,
                          preferred_element_type=jnp.float32)
        M = Gmat * L * dtc.transpose(0, 2, 1)[:, :, None, :]
        y_diag = jnp.einsum("bhqs,bshp->bqhp", M, xc,
                            preferred_element_type=jnp.float32)
        # 2. contribution of the carried-in state
        state_decay = jnp.exp(cs)                  # (B,H,Q)
        y_off = jnp.einsum("bqhn,bhpn,bhq->bqhp", Cc, h, state_decay,
                           preferred_element_type=jnp.float32)
        # 3. next state
        total = cs[..., -1:]                       # (B,H,1)
        rem = jnp.exp(total - cs)                  # (B,H,Q)
        w = dtc * rem.transpose(0, 2, 1)           # (B,Q,H) weight per step
        dBx = jnp.einsum("bqhn,bqhp->bhpn", Bc * w[..., None], xc,
                         preferred_element_type=jnp.float32)
        h_next = jnp.exp(total)[..., None] * h + dBx
        return h_next, y_diag + y_off

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[:, None] * x.astype(jnp.float32)
    y = y.astype(x.dtype)
    if return_state:
        return y, hT
    return y


def ssd_decode_step(
    h: jax.Array,                  # (B, H, P, N) carried state
    x_t: jax.Array,                # (B, H, P)
    dt_t: jax.Array,               # (B, H)
    A: jax.Array,                  # (H,)
    B_t: jax.Array,                # (B, G, N)
    C_t: jax.Array,                # (B, G, N)
    D: jax.Array | None = None,
):
    """One-token SSD recurrence for decode — O(1) in context length."""
    H = x_t.shape[1]
    rep = H // B_t.shape[1]
    Bf = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)      # (B,H,N)
    Cf = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))[..., None, None]
    upd = (dtf[..., None] * x_t.astype(jnp.float32))[..., None] * Bf[:, :, None, :]
    h_new = decay * h.astype(jnp.float32) + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cf)
    if D is not None:
        y = y + D.astype(jnp.float32)[:, None] * x_t.astype(jnp.float32)
    return h_new, y.astype(x_t.dtype)


def ssd(x, dt, A, B_mat, C_mat, D=None, *, initial_state=None,
        return_state=False, policy: KernelPolicy = DEFAULT_POLICY):
    backend = policy.ssd
    if backend == "auto":
        backend = "ref" if x.shape[1] <= 64 else "chunked"
    if backend == "ref":
        return _ref.ssd_ref(x, dt, A, B_mat, C_mat, D,
                            initial_state=initial_state, return_state=return_state)
    if backend == "chunked":
        return ssd_chunked_jnp(x, dt, A, B_mat, C_mat, D, chunk=policy.ssd_chunk,
                               initial_state=initial_state, return_state=return_state)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan
        return ssd_scan.ssd_pallas(x, dt, A, B_mat, C_mat, D,
                                   chunk=policy.ssd_chunk,
                                   initial_state=initial_state,
                                   return_state=return_state,
                                   interpret=backend == "pallas_interpret")
    raise ValueError(f"unknown ssd backend {backend!r}")


# ==========================================================================
# RMSNorm
# ==========================================================================
def rmsnorm(x, scale, *, eps=1e-6, gemma_style=False,
            policy: KernelPolicy = DEFAULT_POLICY):
    backend = policy.rmsnorm
    if backend in ("auto", "ref", "chunked"):
        return _ref.rmsnorm_ref(x, scale, eps=eps, gemma_style=gemma_style)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import rmsnorm as rms
        return rms.rmsnorm_pallas(x, scale, eps=eps, gemma_style=gemma_style,
                                  interpret=backend == "pallas_interpret")
    raise ValueError(f"unknown rmsnorm backend {backend!r}")
