"""Pure-jnp oracles for every kernel.

These are the correctness ground truth: small, obviously-correct,
O(S^2)-memory implementations.  Pallas kernels (and the chunked jnp paths in
ops.py) are validated against these with assert_allclose sweeps in
tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _dequant(cache: jax.Array, scale: jax.Array | None) -> jax.Array:
    """Whole-pool dequant for the quantized oracles: the oracle pays
    O(pool) fp32 memory anyway, so int8 storage simply dequantizes up front
    (``q.astype(f32) * scale`` with the (..., 1) per-row scale broadcasting
    over head_dim) and the unquantized body is reused verbatim."""
    cf = cache.astype(jnp.float32)
    return cf if scale is None else cf * scale


# --------------------------------------------------------------------------
# attention oracle
# --------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,                  # (B, Sq, Hq, D)
    k: jax.Array,                  # (B, Sk, Hkv, D)
    v: jax.Array,                  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int = 0,               # >0: sliding window (q attends to last `window` keys)
    logit_cap: float = 0.0,        # gemma2 tanh softcap
    scale: float | None = None,
    q_offset: int = 0,             # absolute position of q[0] (decode/chunked prefill)
    k_len: jax.Array | None = None,  # valid prefix length of k/v (ragged decode)
) -> jax.Array:
    """Naive GQA attention with all the assigned-arch flavours. fp32 math."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = q_offset + jnp.arange(Sq)[:, None]           # (Sq, 1)
    k_pos = jnp.arange(Sk)[None, :]                      # (1, Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    if k_len is not None:
        mask &= k_pos < jnp.asarray(k_len).reshape(())
    s = jnp.where(mask[None, None, None], s, NEG_INF)

    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, Hq, Dv).astype(q.dtype)


# --------------------------------------------------------------------------
# decode-attention oracle — single token vs a ring-buffer KV cache
# --------------------------------------------------------------------------
def decode_attention_ref(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_pos: jax.Array,              # (C,) absolute position per slot (<0 invalid)
    pos: jax.Array,                # () absolute position of q
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Naive decode oracle: whole-cache fp32 math, explicit slot positions.
    Ground truth for the chunked-jnp path and the split-K Pallas kernel.
    ``k_scale``/``v_scale`` make it the QUANTIZED oracle: the int8 cache is
    dequantized up front and the identical fp32 body runs."""
    B, _, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, _dequant(k_cache, k_scale)) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    valid = (k_pos >= 0) & (k_pos <= pos)
    if window > 0:
        valid &= k_pos > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, _dequant(v_cache, v_scale))
    return o.reshape(B, 1, Hq, v_cache.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# two-stage split-KV oracles — stage-1 partial/LSE contract + stage-2 merge
# --------------------------------------------------------------------------
def merge_kv_splits_ref(partial: jax.Array, lse: jax.Array) -> jax.Array:
    """Stage-2 oracle: merge per-split normalized partials by their
    log-sum-exp weights.  ``partial (..., S, R, Dv)`` + ``lse (..., S, R)``
    -> ``(..., R, Dv)``.  Splits with ``lse == NEG_INF`` (no valid key)
    get weight ~0 and drop out."""
    m = jnp.max(lse, axis=-2, keepdims=True)                  # (..., 1, R)
    w = jnp.exp(lse - m)                                      # (..., S, R)
    den = jnp.maximum(jnp.sum(w, axis=-2), 1e-30)             # (..., R)
    acc = jnp.sum(partial * w[..., None], axis=-3)            # (..., R, Dv)
    return acc / den[..., None]


def _split_partials(s, vf, *, n_units, unit, n_splits):
    """Shared stage-1 oracle body: masked scores ``s (B, Hkv, G, K)`` over
    ``n_units`` blocks of ``unit`` keys each, values ``vf (B, K, Hkv, Dv)``.
    Returns ``(partial (B, Hq, S, 1, Dv), lse (B, Hq, S, 1))`` in the
    Pallas partials layout (head order = kv-head-major, as ``h // G``)."""
    B, Hkv, G, _ = s.shape
    Dv = vf.shape[-1]
    S = max(1, min(int(n_splits), n_units))
    upb = -(-n_units // S)                        # units per split (ceil)
    parts, lses = [], []
    for si in range(S):
        lo = si * upb * unit
        hi = min((si + 1) * upb, n_units) * unit
        if lo >= hi:                              # ragged tail: empty split
            parts.append(jnp.zeros((B, Hkv, G, Dv), jnp.float32))
            lses.append(jnp.full((B, Hkv, G), NEG_INF, jnp.float32))
            continue
        ss = s[..., lo:hi]
        m = jnp.max(ss, axis=-1)                  # (B, Hkv, G)
        p = jnp.exp(ss - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhgk,bkhd->bhgd", p,
                         vf[:, lo:hi].astype(jnp.float32))
        # a split whose every key is masked never runs in the Pallas kernel
        # (l stays 0 there): mirror its zero partial / NEG_INF lse here
        empty = m <= 0.5 * NEG_INF
        part = jnp.where(empty[..., None], 0.0,
                         acc / jnp.maximum(l, 1e-30)[..., None])
        lse = jnp.where(empty, NEG_INF,
                        m + jnp.log(jnp.maximum(l, 1e-30)))
        parts.append(part)
        lses.append(lse)
    Hq = Hkv * G
    partial = jnp.stack(parts, axis=3).reshape(B, Hq, S, 1, Dv)
    lse = jnp.stack(lses, axis=3).reshape(B, Hq, S, 1)
    return partial, lse


def decode_attention_split_ref(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_cache: jax.Array,            # (B, C, Hkv, D)
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_pos: jax.Array,              # (C,) absolute position per slot (<0 invalid)
    pos: jax.Array,                # () absolute position of q
    *, n_splits: int, block_k: int = 256,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Stage-1 oracle for ``decode_attention_pallas_partials``: same
    k-block partitioning (including the divisor-of-C ``block_k``
    adjustment), whole-cache fp32 math per split.  Returns
    ``(partial (B, Hq, S, 1, Dv), lse (B, Hq, S, 1))``."""
    B, _, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, C)
    if C % block_k:
        block_k = next(b for b in range(block_k, 0, -1) if C % b == 0)
    n_k = C // block_k
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, _dequant(k_cache, k_scale)) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    valid = (k_pos >= 0) & (k_pos <= pos)
    if window > 0:
        valid &= k_pos > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    return _split_partials(s, _dequant(v_cache, v_scale), n_units=n_k,
                           unit=block_k, n_splits=n_splits)


def paged_decode_attention_split_ref(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *, n_splits: int,
    window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Stage-1 oracle for ``paged_decode_attention_pallas_partials``: pages
    gathered into logical order, split over pages (the DMA unit)."""
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    Dv = v_pages.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kg = _dequant(k_pages, k_scale)[block_tables].reshape(B, nb * ps, Hkv, D)
    vg = _dequant(v_pages, v_scale)[block_tables].reshape(B, nb * ps, Hkv, Dv)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kg) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(nb * ps)[None, :]
    posb = jnp.asarray(pos).reshape(B, 1)
    valid = k_pos <= posb
    if window > 0:
        valid &= k_pos > posb - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    return _split_partials(s, vg, n_units=nb, unit=ps, n_splits=n_splits)


# --------------------------------------------------------------------------
# MLA compressed-latent paged decode oracles — absorbed-matmul form
# --------------------------------------------------------------------------
def mla_decode_paged_ref(
    q_lat: jax.Array,              # (B, 1, Hq, R) latent queries: [q_abs | q_rope]
    lat_pages: jax.Array,          # (P, ps, R)    latent page pool, R = r_kv + d_rope
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) per-request absolute position of q
    *, r_kv: int, scale: float, logit_cap: float = 0.0,
) -> jax.Array:
    """Naive MLA paged decode oracle in absorbed-matmul form.  One latent
    row per token is shared by every q head (Hkv = 1, G = Hq): the query is
    already projected into latent space (``q_abs = q_nope @ W_uk`` for the
    compressed block, raw ``q_rope`` for the rope sub-block), so a single
    dot of ``q_lat`` against the full latent row computes
    ``q_abs . c_kv + q_rope . k_rope`` in one pass, and the value read is
    the ``[:r_kv]`` slice of the *same* row — the one-DMA-serves-both trick
    the Pallas kernel exploits.  Returns latent outputs ``(B, 1, Hq, r_kv)``
    (the W_uv / W_o expansion happens outside, per the absorbed form).
    ``scale`` is mandatory: MLA scales by the *decompressed* head dim
    ``(d_nope + d_rope) ** -0.5``, not ``R ** -0.5``."""
    B, _, Hq, R = q_lat.shape
    ps = lat_pages.shape[1]
    nb = block_tables.shape[1]
    latg = lat_pages.astype(jnp.float32)[block_tables].reshape(B, nb * ps, R)
    qf = q_lat.astype(jnp.float32).reshape(B, 1, Hq, R)      # (B, Hkv=1, G, R)
    s = jnp.einsum("bhgd,bkd->bhgk", qf, latg) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(nb * ps)[None, :]
    posb = jnp.asarray(pos).reshape(B, 1)
    valid = k_pos <= posb
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkd->bhgd", p, latg[..., :r_kv])
    return o.reshape(B, 1, Hq, r_kv).astype(q_lat.dtype)


def mla_decode_split_ref(
    q_lat: jax.Array,              # (B, 1, Hq, R)
    lat_pages: jax.Array,          # (P, ps, R)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,)
    *, r_kv: int, n_splits: int, scale: float, logit_cap: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Stage-1 oracle for ``mla_paged_decode_attention_pallas_partials``:
    same latent gather as :func:`mla_decode_paged_ref`, split over pages
    (the DMA unit) with the shared ``_split_partials`` body at Hkv = 1,
    G = Hq, Dv = r_kv.  Returns ``(partial (B, Hq, S, 1, r_kv),
    lse (B, Hq, S, 1))`` — merged by the SAME stage-2
    ``merge_kv_splits_pallas`` kernel as every other sweep family."""
    B, _, Hq, R = q_lat.shape
    ps = lat_pages.shape[1]
    nb = block_tables.shape[1]
    latg = lat_pages.astype(jnp.float32)[block_tables].reshape(B, nb * ps, R)
    qf = q_lat.astype(jnp.float32).reshape(B, 1, Hq, R)
    s = jnp.einsum("bhgd,bkd->bhgk", qf, latg) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(nb * ps)[None, :]
    posb = jnp.asarray(pos).reshape(B, 1)
    s = jnp.where((k_pos <= posb)[:, None, None], s, NEG_INF)
    vf = latg[..., :r_kv][:, :, None, :]                      # (B, K, 1, r_kv)
    return _split_partials(s, vf, n_units=nb, unit=ps, n_splits=n_splits)


# --------------------------------------------------------------------------
# verify-attention oracle — K+1 speculative queries vs a ring-buffer cache
# --------------------------------------------------------------------------
def verify_attention_ref(
    q: jax.Array,                  # (B, Q, Hq, D)   Q = K+1 fed tokens
    k_cache: jax.Array,            # (B, C, Hkv, D)  committed through pos-1
    v_cache: jax.Array,            # (B, C, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)  in-flight candidate rows
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    k_pos: jax.Array,              # (C,) absolute position per slot (<0 invalid)
    pos: jax.Array,                # () absolute position of q[:, 0]
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (B, C, Hkv, 1) fp32; int8 caches only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Speculative verify oracle: query i sits at absolute position pos + i
    and attends to (a) the committed cache and (b) candidates j <= i of the
    in-flight block — the candidates' k/v never touch the cache, so a
    rejected suffix needs no rollback.  ``k_scale``/``v_scale`` dequantize
    an int8 cache up front (candidates always stay unquantized).

    Ring-eviction semantics: the sequential decode loop would have
    *overwritten* slots holding positions <= (pos + i) - C by the time it
    reached query i, so those entries are masked here (``k_pos > q_pos - C``)
    even though the verify pass left them physically intact.  This is what
    makes greedy speculative decode bit-identical to the plain loop across
    ring wrap-around."""
    B, Q, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Q, Hkv, G, D)
    q_pos = pos + jnp.arange(Q)[:, None]                     # (Q, 1)

    # (a) committed cache: (B, Hkv, G, Q, C)
    s_c = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                     _dequant(k_cache, k_scale)) * scale
    valid_c = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos) \
        & (k_pos[None, :] > q_pos - C)
    if window > 0:
        valid_c &= k_pos[None, :] > q_pos - window

    # (b) in-flight candidates: causal within the fed block
    s_n = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                     k_new.astype(jnp.float32)) * scale
    n_pos = pos + jnp.arange(Q)[None, :]                     # (1, Q)
    valid_n = n_pos <= q_pos
    if window > 0:
        valid_n &= n_pos > q_pos - window

    s = jnp.concatenate([s_c, s_n], axis=-1)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    valid = jnp.concatenate([valid_c, valid_n], axis=-1)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    vf = jnp.concatenate([_dequant(v_cache, v_scale),
                          v_new.astype(jnp.float32)], axis=1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Q, Hq, Dv).astype(q.dtype)


# --------------------------------------------------------------------------
# paged verify-attention oracle — K+1 speculative queries vs a paged cache
# --------------------------------------------------------------------------
def paged_verify_attention_ref(
    q: jax.Array,                  # (B, Q, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    k_new: jax.Array,              # (B, Q, Hkv, D)    in-flight candidates
    v_new: jax.Array,              # (B, Q, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32
    pos: jax.Array,                # (B,) absolute position of q[:, 0]
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged analogue of :func:`verify_attention_ref`: the pool is committed
    through ``pos[b] - 1`` (linear layout, no eviction), candidates stay
    in-flight.  ``pos`` is per-request — the batch is ragged.
    ``k_scale``/``v_scale`` dequantize an int8 pool up front."""
    B, Q, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    Dv = v_pages.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kg = _dequant(k_pages, k_scale)[block_tables].reshape(B, nb * ps, Hkv, D)
    vg = _dequant(v_pages, v_scale)[block_tables].reshape(B, nb * ps, Hkv, Dv)
    qf = q.astype(jnp.float32).reshape(B, Q, Hkv, G, D)
    q_pos = pos.reshape(B, 1, 1) + jnp.arange(Q)[None, :, None]  # (B, Q, 1)

    s_c = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kg) * scale
    k_pos = jnp.arange(nb * ps)[None, None, :]               # (1, 1, K)
    valid_c = k_pos < pos.reshape(B, 1, 1)                   # committed only
    if window > 0:
        valid_c = valid_c & (k_pos > q_pos - window)
    valid_c = jnp.broadcast_to(valid_c, (B, Q, nb * ps))

    s_n = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                     k_new.astype(jnp.float32)) * scale
    n_pos = pos.reshape(B, 1, 1) + jnp.arange(Q)[None, None, :]
    valid_n = n_pos <= q_pos
    if window > 0:
        valid_n &= n_pos > q_pos - window

    s = jnp.concatenate([s_c, s_n], axis=-1)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    valid = jnp.concatenate([valid_c, valid_n], axis=-1)     # (B, Q, K+Q)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    vf = jnp.concatenate([vg, jnp.asarray(v_new, jnp.float32)], axis=1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Q, Hq, Dv).astype(q.dtype)


# --------------------------------------------------------------------------
# paged decode-attention oracle — single token vs a block-table KV cache
# --------------------------------------------------------------------------
def paged_decode_attention_ref(
    q: jax.Array,                  # (B, 1, Hq, D)
    k_pages: jax.Array,            # (P, ps, Hkv, D)   shared page pool
    v_pages: jax.Array,            # (P, ps, Hkv, Dv)
    block_tables: jax.Array,       # (B, nb) int32 page index per logical block
    pos: jax.Array,                # (B,) per-request absolute position of q
    *, window: int = 0, logit_cap: float = 0.0, scale: float | None = None,
    k_scale: jax.Array | None = None,  # (P, ps, Hkv, 1) fp32; int8 pools only
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Naive paged decode oracle: gather every request's pages into a
    contiguous (B, nb*ps, Hkv, *) view, then whole-cache fp32 math.  Pages
    are laid out linearly (logical block j holds positions [j*ps, (j+1)*ps)),
    so validity is simply k_pos <= pos[b] (+ sliding window).  Ground truth
    for the chunked-jnp path and the block-table-gather Pallas kernel.
    ``k_scale``/``v_scale`` make it the quantized oracle (dequant up front,
    identical body)."""
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kg = _dequant(k_pages, k_scale)[block_tables].reshape(B, nb * ps, Hkv, D)
    vg = _dequant(v_pages, v_scale)[block_tables].reshape(
        B, nb * ps, Hkv, v_pages.shape[-1])
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kg) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(nb * ps)[None, :]                     # (1, K)
    posb = jnp.asarray(pos).reshape(B, 1)
    valid = k_pos <= posb
    if window > 0:
        valid &= k_pos > posb - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vg)
    return o.reshape(B, 1, Hq, v_pages.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba2 SSD oracle — sequential recurrence over time
# --------------------------------------------------------------------------
def ssd_ref(
    x: jax.Array,                  # (B, S, H, P)   inputs per head
    dt: jax.Array,                 # (B, S, H)      softplus'd timestep (>0)
    A: jax.Array,                  # (H,)           negative decay rate
    B_mat: jax.Array,              # (B, S, G, N)   input gates (G groups)
    C_mat: jax.Array,              # (B, S, G, N)   output gates
    D: jax.Array | None = None,    # (H,)           skip connection
    *,
    initial_state: jax.Array | None = None,   # (B, H, P, N)
    return_state: bool = False,
):
    """Exact recurrence:  h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T;
    y_t = C_t h_t + D x_t.  Heads are grouped over B/C like GQA (H % G == 0).
    """
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B_mat.astype(jnp.float32), rep, axis=2)   # (B, S, H, N)
    Cf = jnp.repeat(C_mat.astype(jnp.float32), rep, axis=2)

    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt * Af)[..., None, None]            # (B, H, 1, 1)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, :, None, :]  # (B,H,P,N)
        h = decay * h + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                # (B, S, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[:, None] * xf
    y = y.astype(x.dtype)
    if return_state:
        return y, hT.astype(jnp.float32)
    return y


# --------------------------------------------------------------------------
# RMSNorm oracle
# --------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
                gemma_style: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if gemma_style:
        w = 1.0 + w
    return (y * w).astype(x.dtype)
