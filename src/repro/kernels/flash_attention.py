"""Flash attention as a Pallas TPU kernel.

TPU-native tiling (the hardware-adaptation of the GPU flash algorithm):

  * grid = (batch, q_heads, q_blocks, k_blocks) — the k dimension is
    minor-most, so for a fixed (b, h, iq) the kernel revisits the same
    output tile while streaming k/v blocks HBM -> VMEM; the online-softmax
    running state (m, l, acc) lives in fp32 VMEM scratch across those
    revolutions (this replaces the GPU's shared-memory accumulator).
  * BlockSpec q tile (block_q, D) and k/v tiles (block_k, D) are chosen so
    q + k + v + acc fit VMEM (~2.6 MB at the 512/512 default with D=128)
    and all MXU operands are (8,128)-aligned.
  * GQA is folded into the k/v index_map (q head h reads kv head
    h // (Hq // Hkv)) — no kv replication in HBM.
  * causal / sliding-window masking is computed from block-relative iota;
    fully-masked k blocks are predicated off with pl.when (on real
    hardware a splash-style grid prune would skip their DMA too; the
    roofline accounting uses the jnp chunked path, which skips them
    structurally).

Validated in interpret mode against kernels/ref.py over shape/dtype sweeps
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, logit_cap: float,
               block_q: int, block_k: int, n_k: int, q_offset: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(2)
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # does this (iq, ik) block contain any visible (q, k) pair?
    visible = jnp.bool_(True)
    if causal:
        visible = jnp.logical_and(
            visible, ik * block_k <= q_offset + (iq + 1) * block_q - 1)
    if window > 0:
        visible = jnp.logical_and(
            visible, (ik + 1) * block_k - 1 > q_offset + iq * block_q - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # (B, Sq, Hq, D)
    k: jax.Array,                  # (B, Sk, Hkv, D)
    v: jax.Array,                  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True, window: int = 0, logit_cap: float = 0.0,
    scale: float | None = None, q_offset: int = 0,
    block_q: int = 512, block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        # graceful fallback for ragged shapes, matching the chunked path's
        # behaviour (lazy import: ops imports this module lazily too)
        from repro.kernels.ops import flash_attention_jnp
        return flash_attention_jnp(q, k, v, causal=causal, window=window,
                                   logit_cap=logit_cap, scale=scale,
                                   q_offset=q_offset)
    n_q, n_k = Sq // block_q, Sk // block_k

    qt = q.transpose(0, 2, 1, 3)        # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)        # (B, Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k, n_k=n_k,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running denom l
            pltpu.VMEM((block_q, Dv), jnp.float32),   # running numerator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)    # back to (B, Sq, Hq, Dv)
