"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Tiling: grid = (B, H, n_chunks), chunk index minor-most so the carried SSM
state h (P x N, fp32) persists in VMEM scratch across a head's chunks —
the inter-chunk recurrence never touches HBM.  Per chunk the kernel does
the SSD dual form entirely on MXU-shaped (Q x Q) / (Q x N) / (Q x P)
blocks:

    1. L = exp(segsum(a))              intra-chunk decay, lower-tri
    2. y_diag = ((C B^T) .* L .* dt) x
    3. y_off  = C h_in  .* exp(cumsum a)
    4. h_out  = exp(total) h_in + B^T (dt .* rem .* x)

With Q = 128 (the config default), every operand aligns to the (8, 128)
TPU tile and VMEM use per (b, h) is Q*(2N + 2P + Q) * 4B ≈ 330 KB.

GQA-style B/C groups are folded into the index_map (head h reads group
h // (H // G)).  Validated against kernels/ref.py (exact sequential scan)
in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, h_ref, *,
                chunk: int, has_D: bool):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    A = A_ref[0].astype(jnp.float32)                # ()
    Bm = B_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    Cm = C_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)

    a = dt * A                                      # (Q,)
    cs = jnp.cumsum(a)                              # (Q,)
    # 1. intra-chunk decay matrix
    L = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(L), 0.0)
    # 2. diagonal block
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    M = G * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)
    # 3. carried-state contribution
    h = h_ref[...]                                  # (P, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (Q, P)
    # 4. next state
    total = cs[-1]
    rem = jnp.exp(total - cs)                       # (Q,)
    w = (dt * rem)[:, None] * Bm                    # (Q, N)
    dBx = jax.lax.dot_general(x, w, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = jnp.exp(total) * h + dBx

    if has_D:
        y += D_ref[0].astype(jnp.float32) * x
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_pallas(
    x: jax.Array, dt: jax.Array, A: jax.Array,
    B_mat: jax.Array, C_mat: jax.Array, D: jax.Array | None = None, *,
    chunk: int = 128, initial_state: jax.Array | None = None,
    return_state: bool = False, interpret: bool = False,
):
    """Same contract as ops.ssd_chunked_jnp; initial_state/return_state fall
    back to the jnp path (the kernel is the steady-state training fast path)."""
    if initial_state is not None or return_state:
        from repro.kernels import ops
        return ops.ssd_chunked_jnp(x, dt, A, B_mat, C_mat, D, chunk=chunk,
                                   initial_state=initial_state,
                                   return_state=return_state)
    Bb, S, H, P = x.shape
    Gg, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // Gg
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to the SSD chunk size"
    nc = S // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(Bb, H, nc, chunk, P)
    dtt = dt.transpose(0, 2, 1).reshape(Bb, H, nc, chunk)
    Bt = B_mat.transpose(0, 2, 1, 3).reshape(Bb, Gg, nc, chunk, N)
    Ct = C_mat.transpose(0, 2, 1, 3).reshape(Bb, Gg, nc, chunk, N)
    D_in = D if D is not None else jnp.zeros((H,), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, has_D=D is not None)
    y = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, chunk, N),
                         lambda b, h, c, rep=rep: (b, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N),
                         lambda b, h, c, rep=rep: (b, h // rep, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, H, nc, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, Bt, Ct, D_in)
    return y.reshape(Bb, H, S, P).transpose(0, 2, 1, 3)
