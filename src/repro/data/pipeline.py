"""Deterministic synthetic data pipelines.

No datasets ship in this container, so the pipelines synthesize structured
corpora deterministically from (seed, step, rank):

  * TokenBatches — a Zipf-distributed integer LM stream with short-range
    Markov structure (so losses actually fall during the example runs),
    pre-shifted into (inputs, targets) pairs,
  * CifarBatches — class-conditional Gaussian blobs at 32x32x3 (so CNN
    accuracy rises above chance, which the paper's Fig 2a axis needs).

Determinism contract: batch(step, rank) is a pure function — restart/resume
reproduces the exact stream (checkpoint tests rely on it), and each DP rank
draws a disjoint slice (rank-keyed fold_in).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 256
    seq_len: int = 64
    global_batch: int = 8
    n_codebooks: int = 0          # musicgen-style multi-stream tokens
    zipf_a: float = 1.3
    markov_strength: float = 0.7  # P(next = f(prev)) — learnable structure


class TokenBatches:
    """Synthetic LM token stream."""

    def __init__(self, cfg: DataConfig, rank: int = 0, world: int = 1):
        if cfg.global_batch % world:
            raise ValueError("global_batch must divide by world size")
        self.cfg = cfg
        self.rank = rank
        self.world = world
        self.local_batch = cfg.global_batch // world
        # fixed random permutation = the Markov successor function
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.permutation(cfg.vocab_size)
        # Zipf-ish marginal over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._marginal = p / p.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 7919 + self.rank)
        shape = (self.local_batch, cfg.seq_len + 1)
        if cfg.n_codebooks:
            shape = shape + (cfg.n_codebooks,)
        toks = rng.choice(cfg.vocab_size, size=shape, p=self._marginal)
        # inject Markov structure along the sequence axis
        follow = rng.random(shape[:2]) < cfg.markov_strength
        for t in range(1, cfg.seq_len + 1):
            prev = toks[:, t - 1]
            toks[:, t] = np.where(follow[:, t][..., None] if cfg.n_codebooks
                                  else follow[:, t],
                                  self._succ[prev], toks[:, t])
        toks = toks.astype(np.int32)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class CifarBatches:
    """Class-conditional Gaussian 32x32x3 images, 10 classes (CIFAR stand-in
    for the paper's CNN-zoo benchmarks)."""

    def __init__(self, seed: int = 0, batch: int = 128, n_classes: int = 10):
        self.seed = seed
        self.batch = batch
        self.n_classes = n_classes
        rng = np.random.default_rng(seed)
        # one low-frequency template per class
        base = rng.normal(size=(n_classes, 8, 8, 3)).astype(np.float32)
        self._templates = np.repeat(np.repeat(base, 4, axis=1), 4, axis=2)

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed * 99991 + step)
        labels = rng.integers(0, self.n_classes, size=self.batch)
        noise = rng.normal(scale=0.8, size=(self.batch, 32, 32, 3))
        images = self._templates[labels] + noise.astype(np.float32)
        return images.astype(np.float32), labels.astype(np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batches(cfg: DataConfig, n_steps: int, rank: int = 0,
                 world: int = 1) -> list[dict[str, np.ndarray]]:
    src = TokenBatches(cfg, rank, world)
    return [src.batch(i) for i in range(n_steps)]
