"""Data pipelines: deterministic synthetic corpora, sharded per DP rank."""
from repro.data.pipeline import (CifarBatches, DataConfig, TokenBatches,
                                 make_batches)

__all__ = ["DataConfig", "TokenBatches", "CifarBatches", "make_batches"]
