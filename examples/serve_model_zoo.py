"""Serving example: the whole model zoo on ONE paged engine.

Every family in `src/repro/configs/` — dense/MoE GQA, compressed-latent
MLA (deepseek), sliding-window, local/global, pure and hybrid SSM,
multi-codebook — serves through the same `ServeEngine` continuous-batching
loop; `init_paged_cache` picks the per-family page-pool layout (latent
pools, private windowed rings, O(1) state slots) behind one block-table
seam.  The optional seams (prefix cache, speculative, int8 pages) are
feature-gated per family and report the blocking config field by name —
see the support matrix in docs/serving_engine.md.

    PYTHONPATH=src python examples/serve_model_zoo.py --requests 3 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.serving import EngineConfig, ServeEngine, poisson_trace

ZOO = ["smollm-135m", "deepseek-v2-236b", "h2o-danube-3-4b",
       "gemma2-27b", "mamba2-370m", "zamba2-1.2b", "musicgen-medium"]


def kv_bytes_per_token(cfg, itemsize=2):
    """Decode-cache bytes one new token writes (the HBM the J/token
    metric charges per step; SSM state is O(1) so a token writes none)."""
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.rope_head_dim) * itemsize
    if cfg.uses_ssm and not cfg.hybrid_attn_every:
        return 0
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    return 2 * cfg.padded_kv_heads * hd * itemsize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arches", default=",".join(ZOO))
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    ecfg = EngineConfig(n_slots=2, page_size=4, max_len=48, decode_chunk=4)
    for arch in args.arches.split(","):
        cfg = get_arch(arch).smoke
        params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
        reqs = poisson_trace(args.requests, rate_per_step=0.3, seed=7,
                             vocab_size=cfg.vocab_size, prompt_len=(3, 13),
                             max_new_tokens=(args.gen // 2, args.gen),
                             n_codebooks=cfg.n_codebooks)
        t0 = time.time()
        rep = ServeEngine(cfg, ecfg, params).run(reqs)
        wall = time.time() - t0
        gates = " ".join(f"{name}:{blk[0] if blk else 'ok'}" for name, blk in [
            ("int8", tfm.int8_paged_blockers(cfg)),
            ("spec", tfm.speculative_blockers(cfg)
             or tfm.chunked_prefill_blockers(cfg)),
            ("prefix", tfm.chunked_prefill_blockers(cfg))])
        print(f"[{arch}] {rep.tokens_kept} tokens / {len(rep.results)} reqs "
              f"in {wall:.1f}s, {kv_bytes_per_token(cfg)} KV B/token, "
              f"{gates}")
        first = np.asarray(rep.results[0].tokens).ravel()[:8].tolist()
        print(f"[{arch}] first stream: {first}")


if __name__ == "__main__":
    main()
