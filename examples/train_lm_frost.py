"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full stack — sharded train step, FROST cap tuning from the compiled
step's HLO, checkpoint/restart under the FT supervisor, telemetry ledger.

    PYTHONPATH=src python examples/train_lm_frost.py --steps 300

On this CPU container the default is a scaled-down smollm (the --full flag
uses the real smollm-135m config; ~100M params, a few s/step on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.core import (BALANCED, CapProfiler, PowerCappedDevice, TPU_V5E,
                        WorkloadProfile)
from repro.data import DataConfig, TokenBatches
from repro.launch import hloparse
from repro.optim import OptimizerConfig
from repro.runtime.fault import Supervisor, SupervisorConfig
from repro.runtime.steps import StepConfig, init_train_state, make_train_step
from repro.telemetry.meters import CpuProcessMeter, DramMeter
from repro.telemetry.sampler import PowerSampler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="real smollm-135m (slow on CPU); default reduced")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="kill a worker at this step (recovery drill)")
    ap.add_argument("--ckpt", default="/tmp/frost_lm_ckpt")
    args = ap.parse_args()

    spec = get_arch("smollm-135m")
    cfg = spec.config if args.full else spec.smoke
    print(f"[cfg] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    step_cfg = StepConfig(
        n_micro=2, remat="none",
        optimizer=OptimizerConfig(learning_rate=6e-4, warmup_steps=20,
                                  total_steps=args.steps))
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
    train_step = jax.jit(make_train_step(cfg, step_cfg), donate_argnums=(0,))

    data = TokenBatches(DataConfig(seed=0, vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch))

    # ---- FROST: tune the cap from the compiled step (paper Sec III-C) -----
    compiled = train_step.lower(state, data.batch(0)).compile()
    h = hloparse.analyze(compiled.as_text())
    wl = WorkloadProfile(name=cfg.name, flops_per_step=h["dot_flops"],
                         hbm_bytes_per_step=h["hbm_bytes"],
                         collective_bytes_per_step=h["collective_bytes"],
                         samples_per_step=args.batch)
    dev = PowerCappedDevice(TPU_V5E)

    class Probe:
        def probe(self, cap, duration_s):
            return dev.probe(wl, cap, duration_s)

    decision = CapProfiler(Probe(), policy=BALANCED).run()
    print(f"[frost] step profile: {h['dot_flops']/1e9:.1f} GFLOP, "
          f"{h['hbm_bytes']/1e9:.2f} GB HBM -> cap {decision.cap:.0%} "
          f"(energy {decision.predicted_energy_saving:+.1%}, "
          f"delay {decision.predicted_delay_increase:+.1%})")

    # ---- supervised training with telemetry --------------------------------
    ckpt = CheckpointManager(args.ckpt, keep=2, save_async=True)
    ckpt.save(state, 0)                    # recovery floor before step 1
    sup = Supervisor(SupervisorConfig(checkpoint_every=50),
                     save_fn=lambda s, i: ckpt.save(s, i),
                     restore_fn=lambda: (ckpt.restore(state),
                                         ckpt.latest_step() or 0))
    sup.register("node-0")
    inject = {args.inject_failure: "node-0"} if args.inject_failure else {}

    sampler = PowerSampler({"cpu": CpuProcessMeter(),
                            "dram": DramMeter(4, 16)}, rate_hz=0.5)
    batches = (data.batch(i) for i in range(args.steps))
    t0 = time.time()
    with sampler:
        state, report = sup.run(train_step, state, batches,
                                inject_failure_at=inject)
    dt = time.time() - t0
    ckpt.wait()

    hist = report["history"]
    losses = [h["loss"] for h in hist]
    energy = sampler.ledger.report()
    print(f"[done] {report['final_step']} steps in {dt:.1f}s | "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-20:]):.3f} | "
          f"restarts={report['restarts']}")
    print(f"[energy] gross {energy.gross_j:.1f} J over {energy.duration_s:.1f}s "
          f"(mean {energy.mean_power_w:.1f} W, host meters)")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
