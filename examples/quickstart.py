"""Quickstart: FROST in ~60 lines, batch and closed-loop.

Part 1 profiles a workload's power-cap response the paper's way (8 x 30 s
probe windows), fits the F(x) cost curve, and picks the ED^2P-optimal cap —
showing the A1-policy knob moving the decision.

Part 2 runs the same decision *online*: step telemetry streams over the
control-plane event bus, the ``OnlineCapProfiler`` amortises its probes
across live traffic, and cap commands land mid-run — no dedicated probe
windows.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.control import CapApplied, EventBus, StepDone
from repro.control.online import OnlineCapProfiler
from repro.core import (BALANCED, CapProfiler, ENERGY_LEAN, LATENCY_LEAN,
                        PowerCappedDevice, TPU_V5E, WorkloadProfile)
from repro.core.profiler import RecordingBackend

# 1. Describe a workload by its roofline character (FLOPs + bytes per step).
#    In production these numbers come from the compiled step's HLO
#    (see repro.launch.dryrun); here: a training-like, compute-leaning step.
workload = WorkloadProfile(
    name="demo-train",
    flops_per_step=1.2e12,         # 1.2 TFLOP per step
    hbm_bytes_per_step=6e9,        # 6 GB HBM traffic per step
    samples_per_step=256,
)

# 2. A power-cappable device (TPU v5e here; RTX_3080/3090 = paper's rigs).
device = PowerCappedDevice(TPU_V5E)


class Probe:
    """FROST probes the workload under each cap for ~30 s (paper Sec III-C)."""

    def probe(self, cap: float, duration_s: float):
        return device.probe(workload, cap, duration_s)


# 3. Batch flow: profile -> fit F(x) -> downhill simplex, per A1 policy.
batch_decisions = {}
for policy in (ENERGY_LEAN, BALANCED, LATENCY_LEAN):
    decision = batch_decisions[policy.policy_id] = \
        CapProfiler(Probe(), policy=policy).run()
    print(f"{policy.policy_id:18s} -> cap {decision.cap:5.0%}  "
          f"energy {decision.predicted_energy_saving:+6.1%}  "
          f"delay {decision.predicted_delay_increase:+6.1%}  "
          f"(fit rmse {decision.fit.rel_rmse:.2%}, "
          f"{'accepted' if decision.fit_accepted else 'FALLBACK'})")

# 4. Closed-loop flow: the SAME decision from streamed events — the online
#    profiler probes across live steps instead of freezing the pipeline.
bus = EventBus()
backend = RecordingBackend()
profiler = OnlineCapProfiler(bus, backend, policy=BALANCED,
                             steps_per_probe=2, hold_steps=16,
                             min_refresh_interval_s=0.0)
for step in range(40):                       # live traffic
    cap = backend.current_cap()              # honour the latest cap command
    est = device.estimate(workload, cap)
    bus.publish(StepDone(node_id="node-0", step=step,
                         duration_s=est.step_time_s,
                         samples=workload.samples_per_step,
                         energy_j=est.energy_j))

caps = bus.events_of(CapApplied)
probes = sum(1 for c in caps if c.reason == "probe")
print(f"\nonline: {len(caps)} cap commands over 40 live steps "
      f"({probes} amortised probes) -> cap {profiler.decision.cap:.0%} "
      f"(batch said {batch_decisions[BALANCED.policy_id].cap:.0%})")

# 5. The raw probe curve, if you want to plot Fig 4 yourself:
probes_m = CapProfiler(Probe(), policy=BALANCED).measure()
caps_g = [m.cap for m in probes_m]
energy = [m.energy_per_sample for m in probes_m]
print("\ncap grid   :", [f"{c:.0%}" for c in caps_g])
print("J / sample :", [f"{e:.3f}" for e in energy])
best = caps_g[int(np.argmin(energy))]
print(f"energy-optimal probe: {best:.0%} of TDP")
