"""Quickstart: FROST in ~60 lines.

Profiles a workload's power-cap response, fits the paper's F(x) cost curve,
and picks the ED^2P-optimal cap — then shows the A1-policy knob moving the
decision.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BALANCED, CapProfiler, ENERGY_LEAN, LATENCY_LEAN,
                        PowerCappedDevice, TPU_V5E, WorkloadProfile)

# 1. Describe a workload by its roofline character (FLOPs + bytes per step).
#    In production these numbers come from the compiled step's HLO
#    (see repro.launch.dryrun); here: a training-like, compute-leaning step.
workload = WorkloadProfile(
    name="demo-train",
    flops_per_step=1.2e12,         # 1.2 TFLOP per step
    hbm_bytes_per_step=6e9,        # 6 GB HBM traffic per step
    samples_per_step=256,
)

# 2. A power-cappable device (TPU v5e here; RTX_3080/3090 = paper's rigs).
device = PowerCappedDevice(TPU_V5E)


class Probe:
    """FROST probes the workload under each cap for ~30 s (paper Sec III-C)."""

    def probe(self, cap: float, duration_s: float):
        return device.probe(workload, cap, duration_s)


# 3. Profile -> fit F(x) = a e^(bx-c) + d sigma(ex-f) + g -> downhill simplex.
for policy in (ENERGY_LEAN, BALANCED, LATENCY_LEAN):
    decision = CapProfiler(Probe(), policy=policy).run()
    print(f"{policy.policy_id:18s} -> cap {decision.cap:5.0%}  "
          f"energy {decision.predicted_energy_saving:+6.1%}  "
          f"delay {decision.predicted_delay_increase:+6.1%}  "
          f"(fit rmse {decision.fit.rel_rmse:.2%}, "
          f"{'accepted' if decision.fit_accepted else 'FALLBACK'})")

# 4. The raw probe curve, if you want to plot Fig 4 yourself:
probes = CapProfiler(Probe(), policy=BALANCED).measure()
caps = [m.cap for m in probes]
energy = [m.energy_per_sample for m in probes]
print("\ncap grid   :", [f"{c:.0%}" for c in caps])
print("J / sample :", [f"{e:.3f}" for e in energy])
best = caps[int(np.argmin(energy))]
print(f"energy-optimal probe: {best:.0%} of TDP")
