"""Serving example: batched prefill + continuous decode with a FROST cap
chosen from the DECODE roofline (memory-bound => deep caps near-free).

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --gen 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import BALANCED, CapProfiler, PowerCappedDevice, TPU_V5E, \
    WorkloadProfile
from repro.data import DataConfig, TokenBatches
from repro.launch import hloparse
from repro.models import transformer as tfm
from repro.runtime.steps import StepConfig, make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    step_cfg = StepConfig(remat="none")
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, step_cfg, max_len=max_len))
    serve = jax.jit(make_serve_step(cfg, step_cfg), donate_argnums=(1,))

    data = TokenBatches(DataConfig(seed=1, vocab_size=cfg.vocab_size,
                                   seq_len=args.prompt_len,
                                   global_batch=args.requests,
                                   n_codebooks=cfg.n_codebooks))
    prompts = jnp.asarray(data.batch(0)["inputs"])

    # FROST on the decode graph: profile ONE serve step's roofline
    logits, cache = prefill(params, {"inputs": prompts})
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    tok0 = nxt.reshape(args.requests, 1, -1) if cfg.n_codebooks \
        else nxt.reshape(args.requests, 1)
    compiled = serve.lower(params, cache, tok0).compile()
    h = hloparse.analyze(compiled.as_text())
    wl = WorkloadProfile(name=f"{cfg.name}-decode",
                         flops_per_step=h["dot_flops"],
                         hbm_bytes_per_step=h["hbm_bytes"],
                         samples_per_step=args.requests)
    dev = PowerCappedDevice(TPU_V5E)

    class Probe:
        def probe(self, cap, duration_s):
            return dev.probe(wl, cap, duration_s)

    d = CapProfiler(Probe(), policy=BALANCED).run()
    cfrac = wl.compute_fraction(TPU_V5E)
    print(f"[frost] decode step: {h['dot_flops']/1e6:.1f} MFLOP / "
          f"{h['hbm_bytes']/1e6:.1f} MB -> compute fraction {cfrac:.2f} "
          f"-> cap {d.cap:.0%} (energy {d.predicted_energy_saving:+.1%}, "
          f"delay {d.predicted_delay_increase:+.1%})")

    # decode loop (greedy continuous batch)
    outs = [nxt]
    t0 = time.time()
    tok = tok0
    for _ in range(args.gen - 1):
        nxt, cache = serve(params, cache, tok)
        tok = nxt.reshape(args.requests, 1, -1) if cfg.n_codebooks \
            else nxt.reshape(args.requests, 1)
        outs.append(nxt)
    dt = time.time() - t0
    total = args.gen * args.requests
    print(f"[serve] {total} tokens in {dt*1e3:.0f} ms "
          f"({total/max(dt,1e-9):.0f} tok/s on host CPU)")
    print(f"[serve] first sequence: "
          f"{np.stack([np.asarray(o) for o in outs], 1)[0].ravel()[:20].tolist()}")


if __name__ == "__main__":
    main()
