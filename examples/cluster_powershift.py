"""Cluster power shifting — the Sec II-C capability the paper motivates but
never builds, now closed-loop: a ``ClusterCoordinator`` subscribes to
per-node ``StepDone`` telemetry, *re-estimates* each node's thermal derate
from observed step times, and re-splits the global power budget through the
water-filling allocator — emitting per-node cap commands.

Scenario: a 16-node pod with a 90% global power budget; two nodes are
thermally derated (the canonical stragglers).  Nobody tells the
coordinator which nodes are sick — it finds out from the event stream.
Compare:

  A. uniform capping  — every node gets the same cap,
  B. FROST power shift — slow nodes get more watts, fast nodes get capped
     harder (straggler mitigation at equal budget).

    PYTHONPATH=src python examples/cluster_powershift.py
"""
from __future__ import annotations

import numpy as np

from repro.control import CapApplied, EventBus, PowerSampled, StepDone
from repro.control.coordinator import ClusterCoordinator
from repro.core import ClusterNode, PowerCappedDevice, TPU_V5E, WorkloadProfile

# one pod-slice: 16 nodes, same training step everywhere (DP)
WL = WorkloadProfile(name="train-step", flops_per_step=4e12,
                     hbm_bytes_per_step=3e9, collective_bytes_per_step=5e8,
                     samples_per_step=16)

TRUE_DERATE = {3: 0.78, 11: 0.78}    # ground truth the coordinator must infer

# The devices the pod *actually* runs on (two thermally throttled)...
actual = [PowerCappedDevice(TPU_V5E, derate=TRUE_DERATE.get(i, 1.0))
          for i in range(16)]

budget = 0.90 * 16 * TPU_V5E.tdp_w
print(f"global budget: {budget:.0f} W over 16 nodes "
      f"(2 derated to 0.78 — unknown to the coordinator)\n")

# --- A: uniform cap meeting the budget -------------------------------------
uniform_cap = 0.90
times_uniform = [d.estimate(WL, uniform_cap).step_time_s for d in actual]
power_uniform = [d.estimate(WL, uniform_cap).power_w for d in actual]
t_uniform = max(times_uniform)
e_uniform = sum(power_uniform) * t_uniform
print(f"A. uniform {uniform_cap:.0%} cap : step {t_uniform*1e3:7.1f} ms   "
      f"energy/step {e_uniform:7.1f} J   "
      f"(straggler drag {max(times_uniform)/np.median(times_uniform):.2f}x)")

# --- B: the closed loop -------------------------------------------------------
# ...but the coordinator is registered with HEALTHY node models: the derates
# must be inferred from streamed step telemetry before rebalancing.
bus = EventBus()
coord = ClusterCoordinator(bus, global_budget_w=budget,
                           rebalance_every=3 * 16)
backends = {}
for i in range(16):
    node = ClusterNode(f"node-{i:02d}", PowerCappedDevice(TPU_V5E), WL)
    backends[node.node_id] = coord.register_node(node)

# Simulate three synchronous DP steps: every rank reports its *measured*
# step time under its currently-enforced cap; the third round of reports
# trips the coordinator's rebalance.
for step in range(3):
    for i, dev in enumerate(actual):
        nid = f"node-{i:02d}"
        cap = backends[nid].current_cap()
        est = dev.estimate(WL, cap)
        bus.publish(PowerSampled(node_id=nid, t=float(step),
                                 gpu_w=est.power_w))
        bus.publish(StepDone(node_id=nid, step=step,
                             duration_s=est.step_time_s,
                             samples=WL.samples_per_step,
                             energy_j=est.energy_j))

plan = coord.plans[-1]
print(f"B. FROST shift       : step {plan.step_time_s*1e3:7.1f} ms   "
      f"energy/step {plan.energy_per_step_j:7.1f} J   "
      f"(feasible={plan.feasible})")

derates = coord.derates()
print(f"   inferred derates  : node-03={derates['node-03']:.2f} "
      f"node-11={derates['node-11']:.2f} "
      f"(healthy ~{np.median([v for k, v in derates.items() if k not in ('node-03', 'node-11')]):.2f})")
caps = coord.current_caps()
slow = [f"{k}={v:.0%}" for k, v in caps.items() if k in ("node-03", "node-11")]
fast = [f"{v:.0%}" for v in sorted(v for k, v in caps.items()
                                   if k not in ("node-03", "node-11"))]
n_cmds = len(bus.events_of(CapApplied))
print(f"   derated nodes got: {', '.join(slow)}; "
      f"healthy nodes capped to {fast[0]}..{fast[-1]} "
      f"({n_cmds} cap commands on the bus)")
audit = coord.audit[-1]
# one more telemetry round under the NEW caps so the measured EWMA reflects
# the post-rebalance draw (at rebalance time it still remembers uncapped steps)
for i, dev in enumerate(actual):
    nid = f"node-{i:02d}"
    est = dev.estimate(WL, backends[nid].current_cap())
    bus.publish(PowerSampled(node_id=nid, t=3.0, gpu_w=est.power_w))
measured_now = coord.measured_total_w()
print(f"   budget audit      : allocated {audit['allocated_w']:.0f} W, "
      f"measured {measured_now:.0f} W of {audit['budget_w']:.0f} W "
      f"({'within' if measured_now <= audit['budget_w'] else 'OVER'} budget)")

speedup = t_uniform / plan.step_time_s - 1.0
saving = 1 - plan.energy_per_step_j / e_uniform
print(f"\n=> step time {speedup:+.1%}, energy/step saved {saving:.1%} "
      f"at the SAME global budget — power capping as straggler mitigation, "
      f"driven entirely by streamed telemetry.")
