"""Cluster power shifting — the Sec II-C capability the paper motivates but
never builds: a global power budget split across heterogeneous / thermally
derated nodes so the synchronous DP step time is minimal within the budget.

Scenario: a 16-node pod with a 90% global power budget; two nodes are
thermally derated (the canonical stragglers).  Compare:

  A. uniform capping  — every node gets the same cap,
  B. FROST power shift — slow nodes get more watts, fast nodes get capped
     harder (straggler mitigation at equal budget).

    PYTHONPATH=src python examples/cluster_powershift.py
"""
from __future__ import annotations

import numpy as np

from repro.core import (ClusterNode, PowerCappedDevice, TPU_V5E,
                        WorkloadProfile, allocate_power)

# one pod-slice: 16 nodes, same training step everywhere (DP)
WL = WorkloadProfile(name="train-step", flops_per_step=4e12,
                     hbm_bytes_per_step=3e9, collective_bytes_per_step=5e8,
                     samples_per_step=16)

nodes = []
for i in range(16):
    derate = 1.0
    if i in (3, 11):
        derate = 0.78            # thermally throttled stragglers
    nodes.append(ClusterNode(f"node-{i:02d}",
                             PowerCappedDevice(TPU_V5E, derate=derate), WL))

budget = 0.90 * 16 * TPU_V5E.tdp_w
print(f"global budget: {budget:.0f} W over {len(nodes)} nodes "
      f"(2 derated to 0.78)\n")

# --- A: uniform cap meeting the budget -------------------------------------
uniform_cap = 0.90
times_uniform = [n.step_time(uniform_cap) for n in nodes]
power_uniform = [n.device.estimate(n.workload, uniform_cap).power_w
                 for n in nodes]
t_uniform = max(times_uniform)
e_uniform = sum(power_uniform) * t_uniform
print(f"A. uniform {uniform_cap:.0%} cap : step {t_uniform*1e3:7.1f} ms   "
      f"energy/step {e_uniform:7.1f} J   "
      f"(straggler drag {max(times_uniform)/np.median(times_uniform):.2f}x)")

# --- B: FROST power shift -----------------------------------------------------
plan = allocate_power(nodes, budget)
print(f"B. FROST shift       : step {plan.step_time_s*1e3:7.1f} ms   "
      f"energy/step {plan.energy_per_step_j:7.1f} J   "
      f"(feasible={plan.feasible})")
caps = {a.node_id: a.cap for a in plan.allocations}
slow = [f"{k}={v:.0%}" for k, v in caps.items() if k in ("node-03", "node-11")]
fast = [f"{v:.0%}" for k, v in caps.items()
        if k not in ("node-03", "node-11")]
print(f"   derated nodes got: {', '.join(slow)}; "
      f"healthy nodes capped to {fast[0]}..{fast[-1]}")

speedup = t_uniform / plan.step_time_s - 1.0
saving = 1 - plan.energy_per_step_j / e_uniform
print(f"\n=> step time {speedup:+.1%}, energy/step saved {saving:.1%} "
      f"at the SAME global budget — power capping as straggler mitigation.")
