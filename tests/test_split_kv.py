"""Two-stage split-KV contract tests (interpret-mode parity sweeps).

Every case runs the stage-1 partial sweep + stage-2 LSE merge against BOTH
the single-split kernel (``n_splits=1``, today's bit-exact path) and the
whole-cache ``ref.py`` oracle, across GQA/MQA x ring wrap-around x partial
occupancy x ragged paged ``pos`` x non-divisible split counts.  Greedy
argmax through a projection head must be identical — that is what keeps
the PR 4/5 exactness canaries green when the engine turns splits on.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention as da
from repro.kernels import ops, ref

C = 80                 # ring capacity: 5 blocks of 16
BLOCK_K = 16
PS, NB = 16, 5         # paged: 5 pages of 16
D = DV = 16
Q = 4                  # verify block (K+1)
SPLITS = [1, 2, 5]     # 2 does not divide 5 blocks; 5 = one block per split
TOL = 5e-6

_HEADS = [(4, 2), (4, 1)]          # (Hq, Hkv): GQA and MQA
_POS = {"wrap": C + 15, "partial": 10}   # wrapped ring / mostly-empty cache


def _rng_arrays(B, Hq, Hkv, *, seed=0):
    rng = np.random.default_rng(seed)
    r = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    return {
        "q1": r(B, 1, Hq, D), "qv": r(B, Q, Hq, D),
        "k": r(B, C, Hkv, D), "v": r(B, C, Hkv, DV),
        "kn": r(B, Q, Hkv, D), "vn": r(B, Q, Hkv, DV),
        "kp": r(16, PS, Hkv, D), "vp": r(16, PS, Hkv, DV),
        "bt": jnp.asarray(rng.permutation(16)[:B * NB].reshape(B, NB),
                          jnp.int32),
        "head": r(Hq * DV, 64),
    }


def _argmax(out, head):
    return jnp.argmax(out.reshape(out.shape[0], -1, out.shape[2] * out.shape[3])
                      .sum(axis=1) @ head, axis=-1)


# --------------------------------------------------------------------------
# ring decode
# --------------------------------------------------------------------------
@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("pos_kind", ["wrap", "partial"])
@pytest.mark.parametrize("n_splits", SPLITS)
def test_ring_decode_two_stage_parity(Hq, Hkv, pos_kind, n_splits):
    a = _rng_arrays(2, Hq, Hkv, seed=Hq * 10 + n_splits)
    pos = jnp.int32(_POS[pos_kind])
    k_pos = ops.ring_positions(pos, C)
    oracle = ref.decode_attention_ref(a["q1"], a["k"], a["v"], k_pos, pos)
    single = da.decode_attention_pallas(a["q1"], a["k"], a["v"], pos,
                                        block_k=BLOCK_K, interpret=True)
    two = da.decode_attention_pallas(a["q1"], a["k"], a["v"], pos,
                                     block_k=BLOCK_K, n_splits=n_splits,
                                     interpret=True)
    assert float(jnp.max(jnp.abs(two - oracle))) < TOL
    assert float(jnp.max(jnp.abs(two - single))) < TOL
    assert bool(jnp.all(_argmax(two, a["head"]) == _argmax(oracle, a["head"])))
    if n_splits == 1:              # splits=1 must be bit-for-bit the old path
        assert bool(jnp.all(two == single))


@pytest.mark.parametrize("n_splits", [2, 5])
def test_ring_decode_stage1_matches_split_ref(n_splits):
    """Stage-1 contract: Pallas partials/LSE == the split oracle, including
    empty splits (partial-occupancy cache leaves whole splits without a
    single valid key -> zero partial, NEG_INF lse)."""
    a = _rng_arrays(2, 4, 2, seed=3)
    for pos_v in _POS.values():
        pos = jnp.int32(pos_v)
        k_pos = ops.ring_positions(pos, C)
        pr, lr = ref.decode_attention_split_ref(
            a["q1"], a["k"], a["v"], k_pos, pos, n_splits=n_splits,
            block_k=BLOCK_K)
        pp, lp = da.decode_attention_pallas_partials(
            a["q1"], a["k"], a["v"], pos, n_splits=n_splits,
            block_k=BLOCK_K, interpret=True)
        assert pr.shape == pp.shape and lr.shape == lp.shape
        assert float(jnp.max(jnp.abs(pr - pp))) < TOL
        assert float(jnp.max(jnp.abs(lr - lp))) < 1e-5
        merged = ref.merge_kv_splits_ref(pr, lr)
        oracle = ref.decode_attention_ref(a["q1"], a["k"], a["v"], k_pos, pos)
        assert float(jnp.max(jnp.abs(
            merged[:, :, 0] - oracle[:, 0]))) < TOL


def test_ring_decode_window_and_softcap():
    a = _rng_arrays(2, 4, 2, seed=5)
    pos = jnp.int32(_POS["wrap"])
    k_pos = ops.ring_positions(pos, C)
    for kw in ({"window": 24}, {"logit_cap": 30.0}):
        oracle = ref.decode_attention_ref(a["q1"], a["k"], a["v"], k_pos,
                                          pos, **kw)
        two = da.decode_attention_pallas(a["q1"], a["k"], a["v"], pos,
                                         block_k=BLOCK_K, n_splits=2,
                                         interpret=True, **kw)
        assert float(jnp.max(jnp.abs(two - oracle))) < TOL


# --------------------------------------------------------------------------
# ring verify (candidates fold into the last split)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("pos_kind", ["wrap", "partial"])
@pytest.mark.parametrize("n_splits", SPLITS)
def test_ring_verify_two_stage_parity(Hq, Hkv, pos_kind, n_splits):
    a = _rng_arrays(2, Hq, Hkv, seed=Hq * 20 + n_splits)
    pos = jnp.int32(_POS[pos_kind])
    k_pos = ops.ring_positions(pos - 1, C)
    oracle = ref.verify_attention_ref(a["qv"], a["k"], a["v"], a["kn"],
                                      a["vn"], k_pos, pos)
    single = da.verify_attention_pallas(a["qv"], a["k"], a["v"], a["kn"],
                                        a["vn"], pos, block_k=BLOCK_K,
                                        interpret=True)
    two = da.verify_attention_pallas(a["qv"], a["k"], a["v"], a["kn"],
                                     a["vn"], pos, block_k=BLOCK_K,
                                     n_splits=n_splits, interpret=True)
    assert float(jnp.max(jnp.abs(two - oracle))) < TOL
    assert float(jnp.max(jnp.abs(two - single))) < TOL
    assert bool(jnp.all(_argmax(two, a["head"]) == _argmax(oracle, a["head"])))
    if n_splits == 1:
        assert bool(jnp.all(two == single))


# --------------------------------------------------------------------------
# paged decode / paged verify (ragged per-request pos)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("n_splits", SPLITS)
def test_paged_decode_two_stage_parity(Hq, Hkv, n_splits):
    a = _rng_arrays(3, Hq, Hkv, seed=Hq * 30 + n_splits)
    pos = jnp.asarray([3, 37, 79], jnp.int32)      # ragged occupancy
    oracle = ref.paged_decode_attention_ref(a["q1"][:3], a["kp"], a["vp"],
                                            a["bt"], pos)
    single = da.paged_decode_attention_pallas(a["q1"][:3], a["kp"], a["vp"],
                                              a["bt"], pos, interpret=True)
    two = da.paged_decode_attention_pallas(a["q1"][:3], a["kp"], a["vp"],
                                           a["bt"], pos, n_splits=n_splits,
                                           interpret=True)
    assert float(jnp.max(jnp.abs(two - oracle))) < TOL
    assert float(jnp.max(jnp.abs(two - single))) < TOL
    assert bool(jnp.all(_argmax(two, a["head"]) == _argmax(oracle, a["head"])))
    if n_splits == 1:
        assert bool(jnp.all(two == single))


@pytest.mark.parametrize("n_splits", [2, 5])
def test_paged_decode_stage1_matches_split_ref(n_splits):
    a = _rng_arrays(3, 4, 2, seed=7)
    pos = jnp.asarray([3, 37, 79], jnp.int32)
    pr, lr = ref.paged_decode_attention_split_ref(
        a["q1"][:3], a["kp"], a["vp"], a["bt"], pos, n_splits=n_splits)
    pp, lp = da.paged_decode_attention_pallas_partials(
        a["q1"][:3], a["kp"], a["vp"], a["bt"], pos, n_splits=n_splits,
        interpret=True)
    assert pr.shape == pp.shape and lr.shape == lp.shape
    assert float(jnp.max(jnp.abs(pr - pp))) < TOL
    assert float(jnp.max(jnp.abs(lr - lp))) < 1e-5


@pytest.mark.parametrize("Hq,Hkv", _HEADS)
@pytest.mark.parametrize("n_splits", SPLITS)
def test_paged_verify_two_stage_parity(Hq, Hkv, n_splits):
    a = _rng_arrays(3, Hq, Hkv, seed=Hq * 40 + n_splits)
    pos = jnp.asarray([5, 41, 76], jnp.int32)
    oracle = ref.paged_verify_attention_ref(a["qv"][:3], a["kp"], a["vp"],
                                            a["kn"][:3], a["vn"][:3],
                                            a["bt"], pos)
    single = da.paged_verify_attention_pallas(a["qv"][:3], a["kp"], a["vp"],
                                              a["kn"][:3], a["vn"][:3],
                                              a["bt"], pos, interpret=True)
    two = da.paged_verify_attention_pallas(a["qv"][:3], a["kp"], a["vp"],
                                           a["kn"][:3], a["vn"][:3],
                                           a["bt"], pos, n_splits=n_splits,
                                           interpret=True)
    assert float(jnp.max(jnp.abs(two - oracle))) < TOL
    assert float(jnp.max(jnp.abs(two - single))) < TOL
    assert bool(jnp.all(_argmax(two, a["head"]) == _argmax(oracle, a["head"])))
    if n_splits == 1:
        assert bool(jnp.all(two == single))


# --------------------------------------------------------------------------
# jnp backend split path + policy dispatch + heuristic + fallback warning
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n_splits", [2, 3, 5])
def test_jnp_split_paths_match_single(n_splits):
    a = _rng_arrays(2, 4, 2, seed=11)
    pos = jnp.int32(_POS["wrap"])
    k_pos = ops.ring_positions(pos, C)
    pairs = [
        (ops.decode_attention_jnp(a["q1"], a["k"], a["v"], k_pos, pos),
         ops.decode_attention_jnp(a["q1"], a["k"], a["v"], k_pos, pos,
                                  n_splits=n_splits)),
        (ops.verify_attention_jnp(a["qv"], a["k"], a["v"], a["kn"], a["vn"],
                                  ops.ring_positions(pos - 1, C), pos),
         ops.verify_attention_jnp(a["qv"], a["k"], a["v"], a["kn"], a["vn"],
                                  ops.ring_positions(pos - 1, C), pos,
                                  n_splits=n_splits)),
    ]
    ppos = jnp.asarray([3, 79], jnp.int32)
    bt2 = a["bt"][:2]
    pairs += [
        (ops.paged_decode_attention_jnp(a["q1"], a["kp"], a["vp"], bt2, ppos),
         ops.paged_decode_attention_jnp(a["q1"], a["kp"], a["vp"], bt2, ppos,
                                        n_splits=n_splits)),
        (ops.paged_verify_attention_jnp(a["qv"], a["kp"], a["vp"], a["kn"],
                                        a["vn"], bt2, ppos),
         ops.paged_verify_attention_jnp(a["qv"], a["kp"], a["vp"], a["kn"],
                                        a["vn"], bt2, ppos,
                                        n_splits=n_splits)),
    ]
    for one, many in pairs:
        assert float(jnp.max(jnp.abs(one - many))) < TOL


def test_policy_kv_splits_dispatch():
    """``kv_splits`` on the policy reaches every backend and changes no
    output; ``auto`` equals an explicit 1 on an oversubscribed host."""
    a = _rng_arrays(2, 4, 2, seed=13)
    pos = jnp.int32(_POS["wrap"])
    outs = []
    for kv_splits in ("auto", 1, 4):
        for decode in ("jnp", "pallas_interpret"):
            pol = ops.KernelPolicy(decode=decode, kv_splits=kv_splits,
                                   decode_k_chunk=BLOCK_K)
            outs.append(ops.decode_attention(a["q1"], a["k"], a["v"], pos,
                                             policy=pol))
    base = outs[0]
    for o in outs[1:]:
        assert float(jnp.max(jnp.abs(o - base))) < TOL


def test_choose_kv_splits_occupancy_model():
    # oversubscribed grid: never split (1 = today's behaviour, exactly)
    assert ops.choose_kv_splits(8, 32768, 4, 4) == 1
    # single block: nothing to split
    assert ops.choose_kv_splits(1, 256, 4, 64) == 1
    # deep cache, low batch, idle cores: split to ~2x coverage
    assert ops.choose_kv_splits(1, 32768, 4, 8) == 4
    # never more splits than blocks
    assert ops.choose_kv_splits(1, 512, 1, 64, block=256) <= 2
    # cap at max_splits
    assert ops.choose_kv_splits(1, 10 ** 6, 1, 512) == 16


def test_choose_kv_splits_mla_grid():
    """MLA decode grids have q_heads = 1: all 128 heads share ONE latent
    row per token, so the page DMA is shared and the occupancy cell count
    is just ``batch * splits`` — the deepest underfill in the zoo at low
    batch, exactly where splitting pays."""
    # B=1, one shared kv row, 8 executors: split hard to cover the machine
    assert ops.choose_kv_splits(1, 32768, 1, 8) == 16
    # moderate batch still underfills (8 cells < 2*8): split a little
    assert ops.choose_kv_splits(8, 32768, 1, 8) == 2
    # high batch oversubscribes even at one kv head: never split
    assert ops.choose_kv_splits(16, 32768, 1, 8) == 1
    # never more splits than latent pages
    assert ops.choose_kv_splits(1, 8 * PS, 1, 64, block=PS) <= 8


def test_effective_kv_len_clips_windowed_caches():
    """The split heuristic must see the CLIPPED length on windowed layers:
    a deep sliding-window position is a shallow sweep, and splitting it
    only adds merge traffic."""
    assert ops.effective_kv_len(32768, 512) == 512
    assert ops.effective_kv_len(100, 512) == 100    # min(pos, window)
    assert ops.effective_kv_len(100, 0) == 100      # full attention
    deep_full = ops.choose_kv_splits(1, 32768, 4, 8)
    deep_win = ops.choose_kv_splits(
        1, ops.effective_kv_len(32768, 512), 4, 8, block=256)
    assert deep_full > 1
    # 512 keys = 2 blocks of 256: at most 2 splits, far below the full
    # sweep's choice — the clip is what keeps windowed layers cheap
    assert deep_win <= 2 < deep_full


def test_k_pos_fallback_warns_once():
    a = _rng_arrays(1, 4, 2, seed=17)
    pos = jnp.int32(30)
    k_pos = ops.ring_positions(pos, C)
    pol = ops.KernelPolicy(decode="pallas_interpret", decode_k_chunk=BLOCK_K)
    ops._KPOS_FALLBACK_WARNED.discard("decode_attention")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops.decode_attention(a["q1"], a["k"], a["v"], pos, k_pos=k_pos,
                             policy=pol)
        ops.decode_attention(a["q1"], a["k"], a["v"], pos, k_pos=k_pos,
                             policy=pol)
    hits = [w for w in rec if "Pallas decode" in str(w.message)]
    assert len(hits) == 1 and issubclass(hits[0].category, RuntimeWarning)


# --------------------------------------------------------------------------
# merge kernel in isolation
# --------------------------------------------------------------------------
def test_merge_kernel_matches_ref():
    rng = np.random.default_rng(23)
    partial = jnp.asarray(rng.standard_normal((2, 4, 3, Q, DV)), jnp.float32)
    lse = jnp.asarray(rng.standard_normal((2, 4, 3, Q)), jnp.float32)
    # one split per row empty, as a ragged stage-1 would leave it
    lse = lse.at[:, :, 2, :].set(ref.NEG_INF)
    partial = partial.at[:, :, 2].set(0.0)
    got = da.merge_kv_splits_pallas(partial, lse, out_dtype=jnp.float32,
                                    interpret=True)
    want = ref.merge_kv_splits_ref(partial, lse)
    assert got.shape == (2, 4, Q, DV)
    assert float(jnp.max(jnp.abs(got - want))) < TOL
