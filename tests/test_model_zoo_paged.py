"""Model-zoo paged serving validation.

Every config in ``src/repro/configs`` must ride the paged engine: paged
init + decode must SUCCEED (bit-identical to the ring-cache path) or
raise the named capability error — no silent skips.  On top of the
per-family cache layouts (latent MLA pages, private windowed rings, SSM
state slots, the hybrid shared buffer, stacked first-dense pools), the
engine's greedy streams must stay bit-identical to the solo ring-cache
reference, with prefix sharing, preemption-fold and snapshot/restore
riding along unchanged.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_SPECS, get_arch
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.runtime.steps import StepConfig, make_run_ctx
from repro.serving import (EngineConfig, PagedKVCache, Request, ServeEngine,
                           batch_trace, poisson_trace)

# float32 pools so paged-vs-ring parity is exact rounding-for-rounding
ECFG = EngineConfig(n_slots=2, page_size=4, max_len=48, decode_chunk=4,
                    cache_dtype="float32")

# one representative per newly unlocked family (dense GQA is covered by
# test_serving.py): MLA + first-dense, sliding-window, local/global,
# pure-SSM, hybrid-SSM, multi-codebook
ZOO = ["deepseek-v2-236b", "h2o-danube-3-4b", "gemma2-27b", "mamba2-370m",
       "zamba2-1.2b", "musicgen-medium"]


def _params(cfg):
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return params


# --------------------------------------------------------------------------
# every config: paged init + decode, or the NAMED capability error
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", sorted(ARCH_SPECS))
def test_every_config_pages_or_names_its_blocker(arch):
    """Paged init + a short decode run succeeds for EVERY shipped config —
    bit-identical logits to the ring cache at float32 — or raises a
    ValueError naming the specific blocking feature.  A config that can do
    neither (silent skip, unnamed crash) fails the zoo."""
    cfg = get_arch(arch).smoke
    blockers = tfm.paged_cache_blockers(cfg)
    n_slots, ps, max_blocks = 2, 4, 8
    n_pages = n_slots + n_slots * max_blocks
    if blockers:
        with pytest.raises(ValueError, match=blockers[0]):
            tfm.init_paged_cache(cfg, n_slots, n_pages, ps, max_blocks)
        return

    params = _params(cfg)
    ctx = make_run_ctx(cfg, None, StepConfig(remat="none"))
    pcache = tfm.init_paged_cache(cfg, n_slots, n_pages, ps, max_blocks,
                                  dtype="float32")
    tables = np.stack([n_slots + s * max_blocks + np.arange(max_blocks)
                       for s in range(n_slots)]).astype(np.int32)
    pcache = {**pcache, "block_tables": jnp.asarray(tables)}
    rcache = tfm.init_cache(cfg, n_slots, ps * max_blocks, dtype="float32")

    rng = np.random.default_rng(0)
    shape = (n_slots, 1) + ((cfg.n_codebooks,) if cfg.n_codebooks else ())
    step = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg, ctx))
    for _ in range(3):
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
        pl_, pcache = step(params, pcache, tok)
        rl_, rcache = step(params, rcache, tok)
        np.testing.assert_array_equal(np.asarray(pl_), np.asarray(rl_))


def test_capability_routers_cover_the_zoo():
    """The per-feature routers agree with the shipped configs: nothing
    blocks plain paged serving any more, while int8 pools / speculative /
    chunked prefill each name their specific blocker per family."""
    for arch in sorted(ARCH_SPECS):
        cfg = get_arch(arch).smoke
        assert tfm.paged_cache_blockers(cfg) == ()
    dsk = get_arch("deepseek-v2-236b").smoke
    assert "use_mla" in tfm.int8_paged_blockers(dsk)
    assert "use_mla" in tfm.speculative_blockers(dsk)
    assert tfm.chunked_prefill_blockers(dsk) == ()      # prefix cache rides
    ssm = get_arch("mamba2-370m").smoke
    assert "uses_ssm" in tfm.int8_paged_blockers(ssm)
    assert "uses_ssm" in tfm.chunked_prefill_blockers(ssm)
    win = get_arch("h2o-danube-3-4b").smoke
    assert "sliding_window" in tfm.int8_paged_blockers(win)
    assert tfm.int8_paged_blockers(get_arch("smollm-135m").smoke) == ()


def test_warn_paged_fallback_warns_once():
    """The ring-cache fallback warning fires ONCE per config and names the
    blocking feature (mirrors ``warn_kv_dtype_fallback``)."""
    ops._PAGED_FALLBACK_WARNED.discard("zoo-test-config")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops.warn_paged_fallback("zoo-test-config", "uses_ssm")
        ops.warn_paged_fallback("zoo-test-config", "uses_ssm")
    msgs = [str(w.message) for w in rec
            if issubclass(w.category, RuntimeWarning)]
    assert len(msgs) == 1 and "uses_ssm" in msgs[0]
    ops._PAGED_FALLBACK_WARNED.discard("zoo-test-config")


# --------------------------------------------------------------------------
# engine greedy streams == solo ring-cache reference, per family
# --------------------------------------------------------------------------
def _ring_reference(cfg, params, req):
    """Solo ring-cache run: jitted prefill (the engine's prefill is jitted
    too — XLA fusion changes bf16 rounding vs op-by-op eager) + jitted
    per-token decode."""
    ctx = make_run_ctx(cfg, None, StepConfig(remat="none"))
    pf = jax.jit(lambda p, t: tfm.prefill(p, t, cfg, ctx,
                                          max_len=ECFG.max_len))
    logits, cache = pf(params, jnp.asarray(req.prompt)[None])
    nxt = jnp.argmax(logits[:, req.prompt_len - 1], -1).astype(jnp.int32)
    toks = [np.asarray(nxt[0]).tolist()]
    step = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg, ctx))
    for _ in range(req.max_new_tokens - 1):
        lg, cache = step(params, cache, nxt[:, None])
        nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
        toks.append(np.asarray(nxt[0]).tolist())
    return toks


@pytest.mark.parametrize("arch", ZOO)
def test_engine_streams_match_ring_reference(arch):
    """A mid-stream-interleaving Poisson trace through the paged engine
    emits EXACTLY each request's solo ring-cache greedy stream — latent
    MLA pages, windowed private rings, SSM state slots, the hybrid shared
    buffer and stacked first-dense pools are all invisible in the output."""
    cfg = get_arch(arch).smoke
    params = _params(cfg)
    reqs = poisson_trace(3, rate_per_step=0.3, seed=7,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 13),
                         max_new_tokens=(4, 8),
                         n_codebooks=cfg.n_codebooks)
    rep = ServeEngine(cfg, ECFG, params).run(reqs)
    for r, req in zip(rep.results, reqs):
        np.testing.assert_array_equal(
            np.asarray(r.tokens), np.asarray(_ring_reference(cfg, params,
                                                             req)),
            err_msg=f"{arch} rid {r.rid}")


def test_deepseek_prefix_sharing_parity():
    """MLA latent pages ride the prefix cache: a shared-prefix trace saves
    prefill tokens while every greedy stream stays bit-identical to the
    no-sharing engine (the first-dense stacked pools share the same
    page-id space, so the CoW copy covers them too)."""
    cfg = get_arch("deepseek-v2-236b").smoke
    params = _params(cfg)
    reqs = poisson_trace(4, rate_per_step=0.3, seed=7,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 9),
                         max_new_tokens=(4, 8), shared_prefix_len=11,
                         prompt_pools=2)
    ecfg = dataclasses.replace(ECFG, max_len=64)
    share = ServeEngine(cfg, dataclasses.replace(ecfg, prefix_cache=True),
                        params).run(reqs)
    plain = ServeEngine(cfg, dataclasses.replace(ecfg, prefix_cache=False,
                                                 preempt=False),
                        params).run(reqs)
    assert share.prefill_tokens_saved > 0
    for a, b in zip(share.results, plain.results):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens),
                                      err_msg=f"rid {a.rid}")


def test_deepseek_preemption_and_snapshot_restore(tmp_path):
    """Page-pressure preemption (tokens folded into the requeued prompt)
    and a mid-run crash-restore from snapshot both leave deepseek's greedy
    streams bit-identical to the ample fault-free run.

    fp32 activations: the fold recomputes the folded tokens' latent rows
    through the jitted PREFILL, and XLA's bf16 fusion of the scanned units
    rounds that path differently from the decode step that first wrote
    them (dense GQA rows don't hit this — their per-row matmuls round
    identically either way, which is why test_serving's fold parity holds
    at bf16).  fp32 removes the rounding so the fold itself is tested
    exactly."""
    from repro.runtime.chaos import FaultInjector
    cfg = dataclasses.replace(get_arch("deepseek-v2-236b").smoke,
                              dtype="float32")
    params = _params(cfg)
    reqs = batch_trace(3, seed=5, vocab_size=cfg.vocab_size, prompt_len=6,
                       max_new_tokens=10)
    ample = ServeEngine(cfg, dataclasses.replace(ECFG, prefix_cache=False,
                                                 preempt=False),
                        params).run(reqs)
    base = {r.rid: list(np.asarray(r.tokens).ravel()) for r in ample.results}

    # 2 scratch + 6 usable pages; each context needs ceil((6+10)/4) = 4
    tight = dataclasses.replace(ECFG, n_pages=2 + 6, preempt=True,
                                prefix_cache=False)
    rep = ServeEngine(cfg, tight, params).run(reqs)
    assert rep.n_preemptions > 0
    assert {r.rid: list(np.asarray(r.tokens).ravel())
            for r in rep.results} == base

    inj = FaultInjector()
    inj.schedule("engine_crash", 6)
    eng = ServeEngine(cfg, ECFG, params, injector=inj,
                      snapshot_dir=str(tmp_path), snapshot_every=2)
    from repro.serving import EngineCrash
    try:
        rep2 = eng.run(reqs)
    except EngineCrash:
        eng = ServeEngine.restore(cfg, ECFG, params, str(tmp_path),
                                  injector=inj, snapshot_every=2)
        rep2 = eng.resume()
    assert rep2.n_restores == 1
    assert {r.rid: list(np.asarray(r.tokens).ravel())
            for r in rep2.results} == base


def test_ssm_host_tier_disabled_with_warning():
    """State-slot families have no page pool behind the block tables: the
    host KV tier degrades to off with ONE RuntimeWarning instead of
    paging garbage."""
    cfg = get_arch("mamba2-370m").smoke
    params = _params(cfg)
    ecfg = dataclasses.replace(ECFG, host_tier=True)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, ecfg, params)
    msgs = [str(w.message) for w in rec
            if issubclass(w.category, RuntimeWarning)
            and "host KV tier disabled" in str(w.message)]
    assert len(msgs) == 1
    assert not eng.kv.tables_active


def test_speculative_blocked_by_named_feature():
    """Speculative serving on a non-GQA family raises naming the feature,
    not a generic unsupported error."""
    cfg = get_arch("deepseek-v2-236b").smoke
    params = _params(cfg)
    with pytest.raises(ValueError, match="use_mla"):
        ServeEngine(cfg, dataclasses.replace(ECFG, spec_k=2), params)


def test_windowed_paged_cache_is_o_window():
    """A windowed layer's private ring holds ceil(window/page_size) pages
    per slot — O(window), not O(max_len): the whole point of the per-layer
    page-table groups."""
    cfg = get_arch("h2o-danube-3-4b").smoke
    w = cfg.sliding_window
    assert w and w > 0
    n_slots, ps, max_len = 2, 4, 4 * w
    max_blocks = max_len // ps
    cache = tfm.init_paged_cache(cfg, n_slots,
                                 n_slots + n_slots * max_blocks, ps,
                                 max_blocks)
    nbw = -(-w // ps)
    for name, sub in cache["units"].items():
        if "k" in sub:
            assert sub["k"].shape[1] == n_slots * nbw, name
    kv = PagedKVCache(cfg, n_slots=n_slots, page_size=ps, max_len=max_len)
    assert not kv.tables_active
