"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) and the
chunked-jnp fast paths, all against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_pallas


def _qkv(key, B, Sq, Sk, Hq, Hkv, D, Dv, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dv), dtype)
    return q, k, v


ATTN_SHAPES = [
    # B, Sq, Sk, Hq, Hkv, D, Dv
    (1, 128, 128, 4, 4, 32, 32),      # MHA
    (2, 128, 128, 8, 2, 32, 32),      # GQA 4:1
    (1, 256, 256, 9, 3, 64, 64),      # smollm's awkward 9/3 heads
    (1, 128, 128, 4, 1, 48, 16),      # MQA, Dv != D (MLA-shaped)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_chunked_vs_ref(shape, dtype):
    B, Sq, Sk, Hq, Hkv, D, Dv = shape
    q, k, v = _qkv(jax.random.PRNGKey(1), B, Sq, Sk, Hq, Hkv, D, Dv,
                   jnp.dtype(dtype))
    tol = 2e-5 if dtype == "float32" else 2e-2
    for kwargs in [dict(causal=True), dict(causal=True, window=64),
                   dict(causal=True, logit_cap=30.0), dict(causal=False)]:
        o_ref = ref.attention_ref(q, k, v, **kwargs)
        o = ops.flash_attention_jnp(q, k, v, q_chunk=64, k_chunk=64, **kwargs)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", ATTN_SHAPES[:3])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_pallas_vs_ref(shape, dtype):
    B, Sq, Sk, Hq, Hkv, D, Dv = shape
    q, k, v = _qkv(jax.random.PRNGKey(2), B, Sq, Sk, Hq, Hkv, D, Dv,
                   jnp.dtype(dtype))
    tol = 2e-5 if dtype == "float32" else 2e-2
    for kwargs in [dict(causal=True), dict(causal=True, window=32),
                   dict(causal=True, logit_cap=50.0)]:
        o_ref = ref.attention_ref(q, k, v, **kwargs)
        o = flash_attention(q, k, v, block_q=32, block_k=64, interpret=True,
                            **kwargs)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol)


def test_flash_q_offset_decode_chunk():
    """Chunked prefill continuation: q block at an absolute offset."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 64, 256, 4, 2, 32, 32,
                   jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=True, q_offset=192)
    o = ops.flash_attention_jnp(q, k, v, causal=True, q_offset=192,
                                q_chunk=32, k_chunk=64)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_cache():
    """Ring-buffer decode == full attention at the same absolute position."""
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(4), B, S, S, Hq, Hkv, D, D, jnp.float32)
    # cache smaller than history with window: slot p % C
    C, window = 32, 24
    pos = S - 1
    k_cache = jnp.zeros((B, C, Hkv, D))
    v_cache = jnp.zeros((B, C, Hkv, D))
    for p in range(S):
        k_cache = k_cache.at[:, p % C].set(k[:, p])
        v_cache = v_cache.at[:, p % C].set(v[:, p])
    s = jnp.arange(C)
    k_pos = pos - jnp.mod(pos - s, C)
    o = ops.decode_attention_jnp(q[:, -1:], k_cache, v_cache, k_pos,
                                 jnp.asarray(pos), window=window)
    o_ref = ref.attention_ref(q[:, -1:], k, v, causal=True, window=window,
                              q_offset=pos)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)


SSD_SHAPES = [
    # B, S, H, P, G, N, chunk
    (1, 64, 2, 8, 1, 16, 16),
    (2, 128, 4, 16, 2, 24, 32),
    (1, 128, 8, 64, 1, 128, 64),      # mamba2-370m-like head shape
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_chunked_vs_ref(shape):
    B, S, H, P, G, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D, return_state=True)
    y, h = ops.ssd_chunked_jnp(x, dt, A, Bm, Cm, D, chunk=chunk,
                               return_state=True)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(h, h_ref, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("shape", SSD_SHAPES[:2])
def test_ssd_pallas_vs_ref(shape):
    B, S, H, P, G, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    y_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    y = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)


def test_ssd_initial_state_and_decode_chain():
    """Chunked prefill with carried state == one long exact scan; then the
    O(1) decode steps continue it exactly."""
    B, S, H, P, G, N = 1, 96, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    split = 64
    y1, h1 = ops.ssd_chunked_jnp(x[:, :split], dt[:, :split], A,
                                 Bm[:, :split], Cm[:, :split], None,
                                 chunk=32, return_state=True)
    ys = [y1]
    h = h1
    for t in range(split, S):
        h, yt = ops.ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t],
                                    Cm[:, t], None)
        ys.append(yt[:, None])
    y_chain = jnp.concatenate(ys, axis=1)
    y_ref = ref.ssd_ref(x, dt, A, Bm, Cm, None)
    np.testing.assert_allclose(y_chain, y_ref, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("rows,d", [(32, 64), (100, 96), (256, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("gemma", [False, True])
def test_rmsnorm_pallas(rows, d, dtype, gemma):
    x = jax.random.normal(jax.random.PRNGKey(8), (rows, d), jnp.dtype(dtype))
    w = jax.random.normal(jax.random.PRNGKey(9), (d,))
    o_ref = ref.rmsnorm_ref(x, w, gemma_style=gemma)
    o = rmsnorm_pallas(x, w, gemma_style=gemma, block_rows=32, interpret=True)
    tol = 1e-6 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)
