"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) and the
chunked-jnp fast paths, all against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_verify_attention_pallas,
                                            verify_attention_pallas)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_pallas


def _qkv(key, B, Sq, Sk, Hq, Hkv, D, Dv, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dv), dtype)
    return q, k, v


ATTN_SHAPES = [
    # B, Sq, Sk, Hq, Hkv, D, Dv
    (1, 128, 128, 4, 4, 32, 32),      # MHA
    (2, 128, 128, 8, 2, 32, 32),      # GQA 4:1
    (1, 256, 256, 9, 3, 64, 64),      # smollm's awkward 9/3 heads
    (1, 128, 128, 4, 1, 48, 16),      # MQA, Dv != D (MLA-shaped)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_chunked_vs_ref(shape, dtype):
    B, Sq, Sk, Hq, Hkv, D, Dv = shape
    q, k, v = _qkv(jax.random.PRNGKey(1), B, Sq, Sk, Hq, Hkv, D, Dv,
                   jnp.dtype(dtype))
    tol = 2e-5 if dtype == "float32" else 2e-2
    for kwargs in [dict(causal=True), dict(causal=True, window=64),
                   dict(causal=True, logit_cap=30.0), dict(causal=False)]:
        o_ref = ref.attention_ref(q, k, v, **kwargs)
        o = ops.flash_attention_jnp(q, k, v, q_chunk=64, k_chunk=64, **kwargs)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", ATTN_SHAPES[:3])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_pallas_vs_ref(shape, dtype):
    B, Sq, Sk, Hq, Hkv, D, Dv = shape
    q, k, v = _qkv(jax.random.PRNGKey(2), B, Sq, Sk, Hq, Hkv, D, Dv,
                   jnp.dtype(dtype))
    tol = 2e-5 if dtype == "float32" else 2e-2
    for kwargs in [dict(causal=True), dict(causal=True, window=32),
                   dict(causal=True, logit_cap=50.0)]:
        o_ref = ref.attention_ref(q, k, v, **kwargs)
        o = flash_attention(q, k, v, block_q=32, block_k=64, interpret=True,
                            **kwargs)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol)


def test_flash_q_offset_decode_chunk():
    """Chunked prefill continuation: q block at an absolute offset."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 64, 256, 4, 2, 32, 32,
                   jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=True, q_offset=192)
    o = ops.flash_attention_jnp(q, k, v, causal=True, q_offset=192,
                                q_chunk=32, k_chunk=64)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_cache():
    """Ring-buffer decode == full attention at the same absolute position —
    for every backend behind ops.decode_attention."""
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(4), B, S, S, Hq, Hkv, D, D, jnp.float32)
    # cache smaller than history with window: slot p % C
    C, window = 32, 24
    pos = S - 1
    k_cache = jnp.zeros((B, C, Hkv, D))
    v_cache = jnp.zeros((B, C, Hkv, D))
    for p in range(S):
        k_cache = k_cache.at[:, p % C].set(k[:, p])
        v_cache = v_cache.at[:, p % C].set(v[:, p])
    k_pos = ops.ring_positions(jnp.asarray(pos), C)
    o_ref = ref.attention_ref(q[:, -1:], k, v, causal=True, window=window,
                              q_offset=pos)
    o = ops.decode_attention_jnp(q[:, -1:], k_cache, v_cache, k_pos,
                                 jnp.asarray(pos), window=window)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
    for backend in ("ref", "jnp", "pallas_interpret"):
        pol = ops.KernelPolicy(decode=backend, decode_k_chunk=16)
        o_b = ops.decode_attention(q[:, -1:], k_cache, v_cache,
                                   jnp.asarray(pos), window=window, policy=pol)
        np.testing.assert_allclose(o_b, o_ref, atol=2e-5, rtol=2e-5,
                                   err_msg=backend)


def _ring_cache(key, B, C, Hkv, D, Dv, pos, dtype):
    """Full history of length pos+1 folded into a slot = p % C ring."""
    S = pos + 1
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[1], (B, S, Hkv, Dv), dtype)
    k_cache = jnp.zeros((B, C, Hkv, D), dtype)
    v_cache = jnp.zeros((B, C, Hkv, Dv), dtype)
    for p in range(S):
        k_cache = k_cache.at[:, p % C].set(k[:, p])
        v_cache = v_cache.at[:, p % C].set(v[:, p])
    return k_cache, v_cache


DECODE_SHAPES = [
    # B, C, Hq, Hkv, D, Dv
    (1, 64, 4, 4, 32, 32),      # MHA
    (2, 64, 8, 2, 32, 32),      # GQA 4:1
    (1, 96, 9, 3, 64, 64),      # smollm's awkward 9/3 heads
    (2, 64, 4, 1, 32, 16),      # MQA, Dv != D (MLA-shaped)
]

DECODE_CASES = [
    # pos, window, logit_cap — pos < C-1 leaves unwritten (invalid) slots;
    # pos >= C exercises ring wrap-around
    dict(pos=30, window=0, logit_cap=0.0),      # partial fill, invalid slots
    dict(pos=63, window=0, logit_cap=0.0),      # exactly full
    dict(pos=150, window=48, logit_cap=0.0),    # wrapped + sliding window
    dict(pos=100, window=0, logit_cap=30.0),    # wrapped + tanh softcap
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_pallas_vs_ref(shape, dtype):
    """Split-K Pallas decode kernel (interpret) and the chunk-free jnp path
    vs the whole-cache fp32 oracle, across GQA group sizes, ring wrap,
    sliding window, logit cap, and bf16 storage."""
    B, C, Hq, Hkv, D, Dv = shape
    dt = jnp.dtype(dtype)
    tol = 2e-5 if dtype == "float32" else 2e-2
    for case in DECODE_CASES:
        pos, window, logit_cap = case["pos"], case["window"], case["logit_cap"]
        q = jax.random.normal(jax.random.PRNGKey(pos), (B, 1, Hq, D), dt)
        k_cache, v_cache = _ring_cache(jax.random.PRNGKey(pos + 1),
                                       B, C, Hkv, D, Dv, pos, dt)
        k_pos = ops.ring_positions(jnp.asarray(pos), C)
        o_ref = ref.decode_attention_ref(q, k_cache, v_cache, k_pos,
                                         jnp.asarray(pos), window=window,
                                         logit_cap=logit_cap)
        o_jnp = ops.decode_attention_jnp(q, k_cache, v_cache, k_pos,
                                         jnp.asarray(pos), window=window,
                                         logit_cap=logit_cap)
        # block_k=16 forces a multi-block split-K grid for every C here
        o_pl = decode_attention_pallas(q, k_cache, v_cache, jnp.asarray(pos),
                                       window=window, logit_cap=logit_cap,
                                       block_k=16, interpret=True)
        np.testing.assert_allclose(np.asarray(o_jnp, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol, err_msg=str(case))
        np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   atol=tol, rtol=tol, err_msg=str(case))


def test_decode_invalid_slots_masked():
    """Slots marked invalid (k_pos = -1, e.g. never written) carry no
    weight, whatever garbage their k/v rows hold."""
    B, C, Hkv, D = 1, 16, 2, 32
    pos = 40
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, 4, D))
    k_cache, v_cache = _ring_cache(jax.random.PRNGKey(1), B, C, Hkv, D, D, pos,
                                   jnp.float32)
    k_pos = ops.ring_positions(jnp.asarray(pos), C)
    # poison three slots: mark them invalid and fill with huge values
    bad = jnp.asarray([1, 5, 11])
    k_pos_bad = k_pos.at[bad].set(-1)
    k_poison = k_cache.at[:, bad].set(1e4)
    v_poison = v_cache.at[:, bad].set(1e4)
    o_clean = ops.decode_attention_jnp(
        q, k_cache, v_cache,
        k_pos.at[bad].set(-1), jnp.asarray(pos))
    o_poison = ops.decode_attention_jnp(q, k_poison, v_poison, k_pos_bad,
                                        jnp.asarray(pos))
    np.testing.assert_allclose(o_poison, o_clean, atol=2e-5, rtol=2e-5)
    o_ref = ref.decode_attention_ref(q, k_poison, v_poison, k_pos_bad,
                                     jnp.asarray(pos))
    np.testing.assert_allclose(o_poison, o_ref, atol=2e-5, rtol=2e-5)


def _paged_cache(key, B, ps, nb, P, Hkv, D, Dv, pos, dtype):
    """Per-request linear histories scattered into a shuffled page pool.
    Returns (k_pages, v_pages, tables, ring_caches) where ring_caches[b]
    holds the same history in the canonical slot = p % C ring layout."""
    rng = np.random.default_rng(int(np.sum(pos)))
    k_pages = np.zeros((P, ps, Hkv, D), dtype)
    v_pages = np.zeros((P, ps, Hkv, Dv), dtype)
    perm = rng.permutation(P)
    tables = perm[:B * nb].reshape(B, nb).astype(np.int32)
    rings = []
    C = nb * ps
    for b in range(B):
        S = int(pos[b]) + 1
        ks = jax.random.split(jax.random.fold_in(key, b), 2)
        k = np.asarray(jax.random.normal(ks[0], (S, Hkv, D), dtype))
        v = np.asarray(jax.random.normal(ks[1], (S, Hkv, Dv), dtype))
        for p in range(S):
            page, off = tables[b, p // ps], p % ps
            k_pages[page, off] = k[p]
            v_pages[page, off] = v[p]
        k_ring = np.zeros((C, Hkv, D), dtype)
        v_ring = np.zeros((C, Hkv, Dv), dtype)
        for p in range(S):
            k_ring[p % C] = k[p]
            v_ring[p % C] = v[p]
        rings.append((k_ring, v_ring))
    return jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(tables), rings


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_paged_decode_vs_ring(shape, dtype):
    """Paged decode == ring decode on the same (ragged) histories, for every
    backend behind ops.paged_decode_attention — block-table gather through a
    shuffled physical page layout, per-request positions, partially-filled
    final pages, window and logit-cap flavours, bf16 storage."""
    B, C, Hq, Hkv, D, Dv = shape
    ps, nb, P = 8, C // 8, C // 8 * B + B + 3
    dt = jnp.dtype(dtype)
    tol = 2e-5 if dtype == "float32" else 2e-2
    # ragged depths incl. a page-boundary-1 and a partially-filled page
    pos = np.asarray([(C - 1) if b == 0 else (ps * (2 + b) + b) % (C - 1)
                      for b in range(B)])
    key = jax.random.PRNGKey(11)
    k_pages, v_pages, tables, rings = _paged_cache(
        key, B, ps, nb, P, Hkv, D, Dv, pos, dt)
    q = jax.random.normal(jax.random.PRNGKey(12), (B, 1, Hq, D), dt)
    for case in [dict(window=0, logit_cap=0.0),
                 dict(window=ps * 2, logit_cap=0.0),
                 dict(window=0, logit_cap=30.0)]:
        # ring ground truth, one request at a time (scalar pos)
        o_ring = jnp.concatenate([
            ops.decode_attention_jnp(
                q[b:b + 1], jnp.asarray(rings[b][0])[None],
                jnp.asarray(rings[b][1])[None],
                ops.ring_positions(jnp.asarray(int(pos[b])), nb * ps),
                jnp.asarray(int(pos[b])), **case)
            for b in range(B)], axis=0)
        for backend in ("ref", "jnp", "pallas_interpret"):
            pol = ops.KernelPolicy(decode=backend)
            o = ops.paged_decode_attention(q, k_pages, v_pages, tables,
                                           jnp.asarray(pos), policy=pol,
                                           **case)
            np.testing.assert_allclose(
                np.asarray(o, np.float32), np.asarray(o_ring, np.float32),
                atol=tol, rtol=tol, err_msg=f"{backend} {case}")


VERIFY_CASES = [
    # pos, Q, window, logit_cap — pos < C leaves invalid slots; pos >= C
    # exercises ring wrap (incl. the eviction-semantics mask unique to the
    # verify path: entries the sequential loop would have overwritten)
    dict(pos=20, Q=4, window=0, logit_cap=0.0),     # partial fill
    dict(pos=100, Q=5, window=0, logit_cap=0.0),    # wrapped
    dict(pos=150, Q=3, window=24, logit_cap=0.0),   # wrapped + window
    dict(pos=90, Q=4, window=0, logit_cap=30.0),    # wrapped + softcap
    dict(pos=1, Q=3, window=0, logit_cap=0.0),      # near-empty cache
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_verify_pallas_vs_full_attention(shape, dtype):
    """Speculative verify == full attention over the same history, for every
    backend: Q = K+1 queries at positions pos..pos+Q-1 against a ring
    committed through pos-1 plus the fed block's in-flight k/v.  The ground
    truth is ``attention_ref`` with an effective window of the cache
    capacity — exactly what the sequential decode loop's eviction gives."""
    B, C, Hq, Hkv, D, Dv = shape
    dt = jnp.dtype(dtype)
    tol = 2e-5 if dtype == "float32" else 2e-2
    for case in VERIFY_CASES:
        pos, Q = case["pos"], case["Q"]
        window, logit_cap = case["window"], case["logit_cap"]
        S = pos + Q
        ks = jax.random.split(jax.random.PRNGKey(pos + Q), 3)
        q_full = jax.random.normal(ks[0], (B, S, Hq, D), dt)
        k_full = jax.random.normal(ks[1], (B, S, Hkv, D), dt)
        v_full = jax.random.normal(ks[2], (B, S, Hkv, Dv), dt)
        k_cache = jnp.zeros((B, C, Hkv, D), dt)
        v_cache = jnp.zeros((B, C, Hkv, Dv), dt)
        for p in range(pos):                        # committed prefix only
            k_cache = k_cache.at[:, p % C].set(k_full[:, p])
            v_cache = v_cache.at[:, p % C].set(v_full[:, p])
        q = q_full[:, pos:]
        k_new, v_new = k_full[:, pos:], v_full[:, pos:]
        weff = C if window == 0 else min(window, C)
        o_true = ref.attention_ref(q, k_full, v_full, causal=True,
                                   window=weff, logit_cap=logit_cap,
                                   q_offset=pos)
        k_pos = ops.ring_positions(jnp.asarray(pos - 1), C)
        outs = {
            "ref": ref.verify_attention_ref(
                q, k_cache, v_cache, k_new, v_new, k_pos, jnp.asarray(pos),
                window=window, logit_cap=logit_cap),
            "jnp": ops.verify_attention_jnp(
                q, k_cache, v_cache, k_new, v_new, k_pos, jnp.asarray(pos),
                window=window, logit_cap=logit_cap),
            "pallas": verify_attention_pallas(
                q, k_cache, v_cache, k_new, v_new, jnp.asarray(pos),
                window=window, logit_cap=logit_cap, block_k=16,
                interpret=True),
        }
        for name, o in outs.items():
            np.testing.assert_allclose(
                np.asarray(o, np.float32), np.asarray(o_true, np.float32),
                atol=tol, rtol=tol, err_msg=f"{name} {case}")


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_paged_verify_pallas_vs_full_attention(shape, dtype):
    """Paged speculative verify == full attention per request: shuffled page
    layout, ragged per-request depths, in-flight candidates, window and
    softcap flavours — for every backend behind ops.paged_verify_attention."""
    B, C, Hq, Hkv, D, Dv = shape
    ps, nb = 8, C // 8
    P = B * nb + B + 3
    dt = jnp.dtype(dtype)
    tol = 2e-5 if dtype == "float32" else 2e-2
    Q = 4
    pos = np.asarray([(ps * (2 + b) + 3 * b + 1) % (nb * ps - Q)
                      for b in range(B)])
    rng = np.random.default_rng(int(pos.sum()))
    tables = rng.permutation(P)[:B * nb].reshape(B, nb).astype(np.int32)
    for case in [dict(window=0, logit_cap=0.0),
                 dict(window=ps * 2, logit_cap=0.0),
                 dict(window=0, logit_cap=30.0)]:
        k_pages = np.zeros((P, ps, Hkv, D), dtype)
        v_pages = np.zeros((P, ps, Hkv, Dv), dtype)
        fulls = []
        for b in range(B):
            S = int(pos[b]) + Q
            ks = jax.random.split(jax.random.fold_in(
                jax.random.PRNGKey(17), b), 3)
            qf = jax.random.normal(ks[0], (S, Hq, D), dt)
            kf = jax.random.normal(ks[1], (S, Hkv, D), dt)
            vf = jax.random.normal(ks[2], (S, Hkv, Dv), dt)
            for p in range(int(pos[b])):            # committed rows only
                k_pages[tables[b, p // ps], p % ps] = kf[p]
                v_pages[tables[b, p // ps], p % ps] = vf[p]
            fulls.append((qf, kf, vf))
        q = jnp.stack([f[0][int(pos[b]):] for b, f in enumerate(fulls)])
        k_new = jnp.stack([f[1][int(pos[b]):] for b, f in enumerate(fulls)])
        v_new = jnp.stack([f[2][int(pos[b]):] for b, f in enumerate(fulls)])
        o_true = jnp.stack([
            ref.attention_ref(f[0][None, int(pos[b]):], f[1][None],
                              f[2][None], causal=True, q_offset=int(pos[b]),
                              **case)[0]
            for b, f in enumerate(fulls)])
        kp, vp = jnp.asarray(k_pages), jnp.asarray(v_pages)
        bt, pa = jnp.asarray(tables), jnp.asarray(pos, dtype=jnp.int32)
        outs = {
            "ref": ref.paged_verify_attention_ref(
                q, kp, vp, k_new, v_new, bt, pa, **case),
            "jnp": ops.paged_verify_attention_jnp(
                q, kp, vp, k_new, v_new, bt, pa, **case),
            "pallas": paged_verify_attention_pallas(
                q, kp, vp, k_new, v_new, bt, pa, interpret=True, **case),
        }
        for name, o in outs.items():
            np.testing.assert_allclose(
                np.asarray(o, np.float32), np.asarray(o_true, np.float32),
                atol=tol, rtol=tol, err_msg=f"{name} {case}")


def test_flash_pallas_ragged_fallback():
    """Ragged Sq/Sk no longer assert: the Pallas wrapper falls back to the
    chunked jnp path, matching its behaviour."""
    q, k, v = _qkv(jax.random.PRNGKey(11), 1, 100, 100, 4, 2, 32, 32,
                   jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=True)
    o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)


def test_decode_loop_matches_serve_step():
    """The fused lax.scan decode loop produces the exact token stream of the
    per-token host loop from the same prefill state."""
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    from repro.runtime.steps import (StepConfig, make_decode_loop,
                                     make_prefill_step, make_serve_step)
    cfg = get_arch("smollm-135m").smoke
    step_cfg = StepConfig(remat="none")
    n_tokens = 6
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(make_prefill_step(cfg, step_cfg, max_len=32))
    serve = jax.jit(make_serve_step(cfg, step_cfg))
    loop = jax.jit(make_decode_loop(cfg, step_cfg, n_tokens=n_tokens))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    last_logits, cache = prefill(params, {"inputs": prompts})
    tok0 = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]

    tok, c = tok0, cache
    stream = []
    for _ in range(n_tokens):
        nxt, c = serve(params, c, tok)
        stream.append(np.asarray(nxt))
        tok = nxt[:, None]
    per_token = np.stack(stream, axis=1)            # (B, n_tokens)

    fused, c2 = loop(params, cache, tok0)
    np.testing.assert_array_equal(np.asarray(fused), per_token)
    np.testing.assert_allclose(np.asarray(c2["pos"]), np.asarray(c["pos"]))


SSD_SHAPES = [
    # B, S, H, P, G, N, chunk
    (1, 64, 2, 8, 1, 16, 16),
    (2, 128, 4, 16, 2, 24, 32),
    (1, 128, 8, 64, 1, 128, 64),      # mamba2-370m-like head shape
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_chunked_vs_ref(shape):
    B, S, H, P, G, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D, return_state=True)
    y, h = ops.ssd_chunked_jnp(x, dt, A, Bm, Cm, D, chunk=chunk,
                               return_state=True)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(h, h_ref, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("shape", SSD_SHAPES[:2])
def test_ssd_pallas_vs_ref(shape):
    B, S, H, P, G, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    y_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    y = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=2e-3, rtol=2e-3)


def test_ssd_initial_state_and_decode_chain():
    """Chunked prefill with carried state == one long exact scan; then the
    O(1) decode steps continue it exactly."""
    B, S, H, P, G, N = 1, 96, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    split = 64
    y1, h1 = ops.ssd_chunked_jnp(x[:, :split], dt[:, :split], A,
                                 Bm[:, :split], Cm[:, :split], None,
                                 chunk=32, return_state=True)
    ys = [y1]
    h = h1
    for t in range(split, S):
        h, yt = ops.ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t],
                                    Cm[:, t], None)
        ys.append(yt[:, None])
    y_chain = jnp.concatenate(ys, axis=1)
    y_ref = ref.ssd_ref(x, dt, A, Bm, Cm, None)
    np.testing.assert_allclose(y_chain, y_ref, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("rows,d", [(32, 64), (100, 96), (256, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("gemma", [False, True])
def test_rmsnorm_pallas(rows, d, dtype, gemma):
    x = jax.random.normal(jax.random.PRNGKey(8), (rows, d), jnp.dtype(dtype))
    w = jax.random.normal(jax.random.PRNGKey(9), (d,))
    o_ref = ref.rmsnorm_ref(x, w, gemma_style=gemma)
    o = rmsnorm_pallas(x, w, gemma_style=gemma, block_rows=32, interpret=True)
    tol = 1e-6 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)
