"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import PowerCappedDevice, TPU_V5E, WorkloadProfile, edp
from repro.core.fitting import f_curve
from repro.kernels import ops, ref
from repro.runtime.compress import compress_residual, dequantize_int8

_settings = settings(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
# attention invariants
# --------------------------------------------------------------------------
@_settings
@given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 2),
       st.integers(0, 100))
def test_attention_output_in_value_hull(B, nS, Hkv, seed):
    """Softmax weights are a convex combination: |o|_max <= |v|_max."""
    S, G = 16 * nS, 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv * G, 16))
    k = jax.random.normal(ks[1], (B, S, Hkv, 16))
    v = jax.random.normal(ks[2], (B, S, Hkv, 16))
    o = ops.flash_attention_jnp(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    assert float(jnp.max(jnp.abs(o))) <= float(jnp.max(jnp.abs(v))) + 1e-4


@_settings
@given(st.integers(0, 50))
def test_causal_no_future_leakage(seed):
    """Perturbing token t must not change outputs at positions < t."""
    B, S, H, D = 1, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    t = 20
    o1 = ops.flash_attention_jnp(q, k, v, causal=True, q_chunk=8, k_chunk=8)
    k2 = k.at[:, t:].add(jax.random.normal(ks[3], (B, S - t, H, D)))
    v2 = v.at[:, t:].add(1.0)
    o2 = ops.flash_attention_jnp(q, k2, v2, causal=True, q_chunk=8, k_chunk=8)
    np.testing.assert_allclose(o1[:, :t], o2[:, :t], atol=1e-5)


@_settings
@given(st.integers(8, 24), st.integers(0, 30))
def test_window_equals_truncated_context(window, seed):
    """SWA == full attention over only the last `window` keys (per query)."""
    B, S, H, D = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    o_sw = ref.attention_ref(q, k, v, causal=True, window=window)
    # check the last query explicitly against a hand-truncated context
    lo = S - window
    o_trunc = ref.attention_ref(q[:, -1:], k[:, lo:], v[:, lo:], causal=False)
    np.testing.assert_allclose(o_sw[:, -1:], o_trunc, atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# SSD invariants
# --------------------------------------------------------------------------
@_settings
@given(st.floats(0.25, 4.0), st.integers(0, 30))
def test_ssd_linear_in_x(alpha, seed):
    """With gates fixed, the SSD map is linear in x (it IS a linear SSM)."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y1 = ops.ssd_chunked_jnp(x, dt, A, Bm, Cm, None, chunk=16)
    y2 = ops.ssd_chunked_jnp(alpha * x, dt, A, Bm, Cm, None, chunk=16)
    np.testing.assert_allclose(np.asarray(y2), alpha * np.asarray(y1),
                               rtol=2e-3, atol=2e-3)


@_settings
@given(st.integers(0, 30))
def test_ssd_state_decays(seed):
    """A < 0 ==> with zero input the state contribution decays to zero."""
    B, S, H, P, G, N = 1, 64, 2, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, H))) + 0.5
    A = -jnp.exp(jax.random.normal(ks[1], (H,))) - 0.5
    Bm = jax.random.normal(ks[2], (B, S, G, N))
    Cm = jax.random.normal(ks[3], (B, S, G, N))
    h0 = 5.0 * jnp.ones((B, H, P, N))
    y, hT = ops.ssd_chunked_jnp(jnp.zeros((B, S, H, P)), dt, A, Bm, Cm, None,
                                chunk=16, initial_state=h0, return_state=True)
    assert float(jnp.max(jnp.abs(hT))) < float(jnp.max(jnp.abs(h0)))


# --------------------------------------------------------------------------
# FROST invariants
# --------------------------------------------------------------------------
@_settings
@given(st.floats(1e-3, 1e3), st.floats(1e-3, 1e3), st.floats(0.0, 4.0))
def test_edp_positive_and_monotone(e, d, m):
    assert edp(e, d, m) >= 0
    assert edp(2 * e, d, m) > edp(e, d, m)


@_settings
@given(st.floats(0.3, 1.0), st.floats(0.3, 1.0),
       st.floats(1e11, 1e13), st.floats(1e8, 1e11))
def test_device_model_monotone_in_cap(c1, c2, flops, bts):
    """Lower cap never makes the step FASTER, never raises board power."""
    dev = PowerCappedDevice(TPU_V5E)
    wl = WorkloadProfile(name="w", flops_per_step=flops,
                         hbm_bytes_per_step=bts)
    lo, hi = sorted((c1, c2))
    e_lo, e_hi = dev.estimate(wl, lo), dev.estimate(wl, hi)
    assert e_lo.step_time_s >= e_hi.step_time_s - 1e-9
    assert e_lo.power_w <= e_hi.power_w + 1e-6


@_settings
@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=64),
       st.integers(0, 20))
def test_quantize_error_bounded_by_half_step(vals, seed):
    x = jnp.asarray(vals, jnp.float32)
    q, scale, err = compress_residual(x)
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 + 1e-6


@_settings
@given(st.floats(-2.0, 2.0), st.floats(-5.0, 5.0), st.floats(-5.0, 5.0))
def test_f_curve_finite_everywhere(a, b, c):
    """Eq (6) must never overflow for any coefficients the fitter visits."""
    x = np.linspace(0.0, 1.0, 50)
    y = f_curve(x, (a, b, c, a, b, c, 1.0))
    assert np.all(np.isfinite(y))
