"""Property-based tests (hypothesis) on system invariants."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import PowerCappedDevice, TPU_V5E, WorkloadProfile, edp
from repro.core.fitting import f_curve
from repro.kernels import ops, ref
from repro.runtime.compress import compress_residual, dequantize_int8

_settings = settings(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
# attention invariants
# --------------------------------------------------------------------------
@_settings
@given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 2),
       st.integers(0, 100))
def test_attention_output_in_value_hull(B, nS, Hkv, seed):
    """Softmax weights are a convex combination: |o|_max <= |v|_max."""
    S, G = 16 * nS, 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv * G, 16))
    k = jax.random.normal(ks[1], (B, S, Hkv, 16))
    v = jax.random.normal(ks[2], (B, S, Hkv, 16))
    o = ops.flash_attention_jnp(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    assert float(jnp.max(jnp.abs(o))) <= float(jnp.max(jnp.abs(v))) + 1e-4


@_settings
@given(st.integers(0, 50))
def test_causal_no_future_leakage(seed):
    """Perturbing token t must not change outputs at positions < t."""
    B, S, H, D = 1, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    t = 20
    o1 = ops.flash_attention_jnp(q, k, v, causal=True, q_chunk=8, k_chunk=8)
    k2 = k.at[:, t:].add(jax.random.normal(ks[3], (B, S - t, H, D)))
    v2 = v.at[:, t:].add(1.0)
    o2 = ops.flash_attention_jnp(q, k2, v2, causal=True, q_chunk=8, k_chunk=8)
    np.testing.assert_allclose(o1[:, :t], o2[:, :t], atol=1e-5)


@_settings
@given(st.integers(8, 24), st.integers(0, 30))
def test_window_equals_truncated_context(window, seed):
    """SWA == full attention over only the last `window` keys (per query)."""
    B, S, H, D = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    o_sw = ref.attention_ref(q, k, v, causal=True, window=window)
    # check the last query explicitly against a hand-truncated context
    lo = S - window
    o_trunc = ref.attention_ref(q[:, -1:], k[:, lo:], v[:, lo:], causal=False)
    np.testing.assert_allclose(o_sw[:, -1:], o_trunc, atol=1e-5, rtol=1e-5)


@_settings
@given(st.integers(2, 8), st.integers(0, 1000))
def test_split_merge_any_order_and_grouping_exact(S, seed):
    """Two-stage split-KV soundness: LSE-merging per-split partials is
    permutation- AND grouping-invariant — any merge order or tree shape
    reproduces the full softmax output (so greedy argmax through a
    projection head can never flip with the split schedule), including in
    the presence of an empty split (zero partial, NEG_INF lse)."""
    K, Dv = 40, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = 3.0 * jax.random.normal(ks[0], (K,))
    v = jax.random.normal(ks[1], (K, Dv))
    head = jax.random.normal(ks[2], (Dv, 32))
    oracle = jax.nn.softmax(s) @ v

    # stage 1: ragged contiguous slices + one deliberately empty split
    rng = np.random.default_rng(seed)
    bounds = [0] + sorted(set(rng.integers(1, K, size=S - 1).tolist())) + [K]
    splits = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        m = jnp.max(s[lo:hi])
        w = jnp.exp(s[lo:hi] - m)
        l = jnp.sum(w)
        splits.append(((w @ v[lo:hi]) / l, m + jnp.log(l)))
    splits.append((jnp.zeros(Dv), jnp.asarray(ref.NEG_INF)))
    order = rng.permutation(len(splits))

    def _flat(items):
        partial = jnp.stack([p for p, _ in items])[:, None, :]  # (n, 1, Dv)
        lse = jnp.stack([l for _, l in items])[:, None]         # (n, 1)
        m = jnp.max(lse)
        return (ref.merge_kv_splits_ref(partial, lse)[0],
                m + jnp.log(jnp.sum(jnp.exp(lse - m))))

    permuted = [splits[i] for i in order]
    flat, _ = _flat(permuted)                      # one n-way merge
    tree = permuted[0]
    for item in permuted[1:]:                      # left-deep pairwise merges
        tree = _flat([tree, item])
    cut = int(rng.integers(1, len(permuted)))      # two-group merge
    grouped, _ = _flat([_flat(permuted[:cut]), _flat(permuted[cut:])])

    want = jnp.argmax(oracle @ head)
    for got in (flat, tree[0], grouped):
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   atol=1e-5, rtol=1e-5)
        assert int(jnp.argmax(got @ head)) == int(want)


# --------------------------------------------------------------------------
# SSD invariants
# --------------------------------------------------------------------------
@_settings
@given(st.floats(0.25, 4.0), st.integers(0, 30))
def test_ssd_linear_in_x(alpha, seed):
    """With gates fixed, the SSD map is linear in x (it IS a linear SSM)."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y1 = ops.ssd_chunked_jnp(x, dt, A, Bm, Cm, None, chunk=16)
    y2 = ops.ssd_chunked_jnp(alpha * x, dt, A, Bm, Cm, None, chunk=16)
    np.testing.assert_allclose(np.asarray(y2), alpha * np.asarray(y1),
                               rtol=2e-3, atol=2e-3)


@_settings
@given(st.integers(0, 30))
def test_ssd_state_decays(seed):
    """A < 0 ==> with zero input the state contribution decays to zero."""
    B, S, H, P, G, N = 1, 64, 2, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, H))) + 0.5
    A = -jnp.exp(jax.random.normal(ks[1], (H,))) - 0.5
    Bm = jax.random.normal(ks[2], (B, S, G, N))
    Cm = jax.random.normal(ks[3], (B, S, G, N))
    h0 = 5.0 * jnp.ones((B, H, P, N))
    y, hT = ops.ssd_chunked_jnp(jnp.zeros((B, S, H, P)), dt, A, Bm, Cm, None,
                                chunk=16, initial_state=h0, return_state=True)
    assert float(jnp.max(jnp.abs(hT))) < float(jnp.max(jnp.abs(h0)))


# --------------------------------------------------------------------------
# FROST invariants
# --------------------------------------------------------------------------
@_settings
@given(st.floats(1e-3, 1e3), st.floats(1e-3, 1e3), st.floats(0.0, 4.0))
def test_edp_positive_and_monotone(e, d, m):
    assert edp(e, d, m) >= 0
    assert edp(2 * e, d, m) > edp(e, d, m)


@_settings
@given(st.floats(0.3, 1.0), st.floats(0.3, 1.0),
       st.floats(1e11, 1e13), st.floats(1e8, 1e11))
def test_device_model_monotone_in_cap(c1, c2, flops, bts):
    """Lower cap never makes the step FASTER, never raises board power."""
    dev = PowerCappedDevice(TPU_V5E)
    wl = WorkloadProfile(name="w", flops_per_step=flops,
                         hbm_bytes_per_step=bts)
    lo, hi = sorted((c1, c2))
    e_lo, e_hi = dev.estimate(wl, lo), dev.estimate(wl, hi)
    assert e_lo.step_time_s >= e_hi.step_time_s - 1e-9
    assert e_lo.power_w <= e_hi.power_w + 1e-6


@_settings
@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=64),
       st.integers(0, 20))
def test_quantize_error_bounded_by_half_step(vals, seed):
    x = jnp.asarray(vals, jnp.float32)
    q, scale, err = compress_residual(x)
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 + 1e-6


@_settings
@given(st.floats(-2.0, 2.0), st.floats(-5.0, 5.0), st.floats(-5.0, 5.0))
def test_f_curve_finite_everywhere(a, b, c):
    """Eq (6) must never overflow for any coefficients the fitter visits."""
    x = np.linspace(0.0, 1.0, 50)
    y = f_curve(x, (a, b, c, a, b, c, 1.0))
    assert np.all(np.isfinite(y))


# --------------------------------------------------------------------------
# prefix-sharing paged KV cache invariants
# --------------------------------------------------------------------------
def _kv_check(kv):
    """Structural invariants of the ref-counted prefix-sharing page pool:
    no leak, no double-free, refcounts == holders exactly, scratch parking
    preserved."""
    holders = np.zeros_like(kv.refcount)
    for slot, pages in kv.allocated.items():
        for p in pages:
            assert p >= kv.n_slots, "scratch page mapped as allocation"
            holders[p] += 1
        # tail rows beyond the allocation are parked on the slot's scratch
        assert (kv.tables[slot, len(pages):] == slot).all()
        assert (kv.tables[slot, :len(pages)] == pages).all()
    from repro.serving.paged_kv import HOST_PAGE
    n_host = 0
    stack = [kv._root]
    while stack:
        node = stack.pop()
        if node is not kv._root:
            if node.page == HOST_PAGE:        # demoted: host tier only
                assert node.host_data is not None, "demoted node lost blob"
                n_host += 1
            else:
                assert node.page >= kv.n_slots, "scratch page in the trie"
                assert node.host_data is None, "page resident in both tiers"
                holders[node.page] += 1
        stack.extend(node.children.values())
    if kv.host_pages is not None:
        assert n_host <= kv.host_pages, "host pool budget exceeded"
    for page, n in kv._copy_holds.items():
        assert n > 0
        holders[page] += n
    assert (kv.refcount == holders).all(), "refcount != actual holders"
    free = list(kv.free)
    assert len(free) == len(set(free)), "double-free: duplicate free page"
    assert all(p >= kv.n_slots for p in free), "scratch page freed"
    # a page is free exactly when its last holder released it —
    # quarantined pages are deliberately withheld from circulation
    zero = {int(p) for p in np.nonzero(kv.refcount == 0)[0]
            if p >= kv.n_slots} - kv.quarantined
    assert not (set(free) & kv.quarantined), "quarantined page circulating"
    assert set(free) == zero, "leak: zero-refcount page not in free list"
    for slot in range(kv.n_slots):
        assert kv.refcount[slot] == 0
        bound = [s for s, pages in kv.allocated.items()
                 if slot in pages]
        assert not bound, "scratch page cross-mapped"


@_settings
@given(st.integers(0, 10_000))
def test_paged_kv_invariants_under_random_ops(seed):
    """Random admit/share/ensure/register/release/preempt sequences keep
    the pool sound: pages are never leaked or double-freed, refcounts hit
    zero exactly when the last holder (slot, trie, or pending copy) lets
    go, and scratch parking survives everything.  A tiny vocabulary makes
    prefix collisions (and therefore sharing + CoW) frequent."""
    from repro.configs import get_arch
    from repro.serving import PagedKVCache
    cfg = get_arch("smollm-135m").smoke
    rng = np.random.default_rng(seed)
    kv = PagedKVCache(cfg, n_slots=3, page_size=4, max_len=32,
                      n_pages=3 + rng.integers(6, 14))
    prompts: dict[int, np.ndarray] = {}
    for _ in range(40):
        op = rng.integers(0, 5)
        free_slots = [s for s in range(kv.n_slots) if s not in kv.allocated]
        live = list(kv.allocated)
        if op == 0 and free_slots:                       # admit (maybe share)
            slot = int(rng.choice(free_slots))
            tokens = rng.integers(0, 3, size=int(rng.integers(1, 21)))
            tokens = tokens.astype(np.int32)
            n_alloc = min(len(tokens) + int(rng.integers(0, 8)), kv.max_len)
            if kv.can_admit_with_prefix(tokens, n_alloc):
                m, copy = kv.admit_with_prefix(slot, tokens, n_alloc)
                assert 0 <= m <= len(tokens) - 1
                prompts[slot] = tokens
                if copy is not None:
                    _kv_check(kv)                        # holds visible
                    kv.copy_done(copy.src_page)
        elif op == 1 and live:                           # ensure (grow)
            slot = int(rng.choice(live))
            kv.ensure(slot, int(rng.integers(1, kv.max_len + 1)))
        elif op == 2 and live:                           # register prefix
            slot = int(rng.choice(live))
            t = prompts[slot]
            kv.register_prefix(slot, t[:int(rng.integers(0, len(t) + 1))])
        elif op == 3 and live:                           # release
            slot = int(rng.choice(live))
            kv.release(slot)
            prompts.pop(slot, None)
        elif op == 4 and live:                           # preempt = reg + rel
            slot = int(rng.choice(live))
            kv.register_prefix(slot, prompts[slot])
            kv.release(slot)
            prompts.pop(slot, None)
        _kv_check(kv)
    for slot in list(kv.allocated):
        kv.release(slot)
        _kv_check(kv)
    # with every slot gone, only the trie holds pages — all evictable
    assert int((kv.refcount > 0).sum()) == kv.n_evictable()


@_settings
@given(st.integers(0, 10_000))
def test_two_tier_invariants_under_random_ops(seed):
    """Host-tier variant: the same random traffic against a two-tier pool
    keeps both tiers sound — ``verify_invariants`` stays clean after every
    op (no page in both tiers, refcounts exact, host budget respected),
    ``can_admit_with_prefix`` returning True means the admission cannot
    fail, every demotion fetches exactly one host blob, and a snapshot
    round-trips both tiers bit-exactly (host blobs included)."""
    from repro.configs import get_arch
    from repro.serving import PagedKVCache
    cfg = get_arch("smollm-135m").smoke
    rng = np.random.default_rng(seed)
    host_pages = int(rng.integers(0, 7)) or None
    kv = PagedKVCache(cfg, n_slots=3, page_size=4, max_len=32,
                      n_pages=3 + int(rng.integers(4, 12)),
                      host_tier=True, host_pages=host_pages)
    fetched = {"n": 0}
    restored: list[int] = []

    def fetch(page):                       # fake D2H: content tags the page
        fetched["n"] += 1
        return {"blk/k": np.full((4,), page, np.int32),
                "stamp": np.asarray([fetched["n"]])}

    def restore(page, blob):               # fake H2D
        assert set(blob) == {"blk/k", "stamp"}
        restored.append(int(page))

    kv.attach_tier(fetch, restore, page_bytes=256)
    prompts: dict[int, np.ndarray] = {}
    for _ in range(40):
        op = int(rng.integers(0, 5))
        free_slots = [s for s in range(kv.n_slots) if s not in kv.allocated]
        live = list(kv.allocated)
        if op == 0 and free_slots:                   # admit (maybe promote)
            slot = int(rng.choice(free_slots))
            tokens = rng.integers(0, 3, size=int(rng.integers(1, 21)))
            tokens = tokens.astype(np.int32)
            n_alloc = min(len(tokens) + int(rng.integers(0, 8)), kv.max_len)
            if kv.can_admit_with_prefix(tokens, n_alloc):
                m, copy = kv.admit_with_prefix(slot, tokens, n_alloc)
                assert 0 <= m <= len(tokens) - 1
                prompts[slot] = tokens
                if copy is not None:
                    kv.copy_done(copy.src_page)
        elif op == 1 and live:                       # ensure (grow)
            slot = int(rng.choice(live))
            kv.ensure(slot, int(rng.integers(1, kv.max_len + 1)))
        elif op == 2 and live:                       # register prefix
            slot = int(rng.choice(live))
            t = prompts[slot]
            kv.register_prefix(slot, t[:int(rng.integers(0, len(t) + 1))])
        elif op == 3 and live:                       # release
            slot = int(rng.choice(live))
            kv.release(slot)
            prompts.pop(slot, None)
        elif op == 4 and live:                       # preempt = reg + rel
            slot = int(rng.choice(live))
            kv.register_prefix(slot, prompts[slot])
            kv.release(slot)
            prompts.pop(slot, None)
        _kv_check(kv)
        assert kv.verify_invariants() == []
    assert kv.n_demotions == fetched["n"]
    assert kv.n_promotions == len(restored)
    assert kv.transfer_j == pytest.approx(
        (kv.transfer_bytes_d2h + kv.transfer_bytes_h2d)
        * kv.transfer_j_per_byte)
    # snapshot/restore round-trips both tiers (host blobs included)
    state = kv.state_dict()
    kv2 = PagedKVCache(cfg, n_slots=3, page_size=4, max_len=32,
                       n_pages=kv.n_pages, host_tier=True,
                       host_pages=host_pages)
    kv2.attach_tier(fetch, restore, page_bytes=256)
    kv2.load_state(state)
    assert kv2.verify_invariants() == []
    assert kv2.n_host_used() == kv.n_host_used()
    assert kv2.state_dict() == state


# --------------------------------------------------------------------------
# crash-restore exactness under random fault schedules
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _chaos_model():
    import dataclasses
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    spec = get_arch("smollm-135m")
    cfg = dataclasses.replace(spec.smoke, d_model=64, d_ff=128, head_dim=16)
    params, _ = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_crash_restore_exact_under_random_faults(seed):
    """For ANY fault schedule (engine crash at a random step, plus random
    slot crashes / page corruptions / emergency-cap windows), restoring
    from the last snapshot and resuming yields greedy streams bit-identical
    to the fault-free run, and the paged-KV pool passes the full structural
    audit afterwards."""
    import tempfile
    from repro.runtime.chaos import FaultInjector
    from repro.serving import (EngineConfig, EngineCrash, ServeEngine,
                               poisson_trace)
    cfg, params = _chaos_model()
    ecfg = EngineConfig(n_slots=2, page_size=4, max_len=48, decode_chunk=4)
    rng = np.random.default_rng(seed)
    trace = poisson_trace(4, rate_per_step=0.4, seed=int(rng.integers(100)),
                          vocab_size=cfg.vocab_size, prompt_len=(3, 10),
                          max_new_tokens=(4, 9))
    base = ServeEngine(cfg, ecfg, params).run(trace)

    inj = FaultInjector(seed=seed)
    inj.schedule("engine_crash", int(rng.integers(4, 25)))
    if rng.random() < 0.5:
        inj.schedule("slot_crash", int(rng.integers(2, 20)),
                     arg=int(rng.integers(2)))
    if rng.random() < 0.5:
        inj.schedule("page_corrupt", int(rng.integers(2, 20)))
    if rng.random() < 0.5:
        inj.schedule("emergency_cap", int(rng.integers(2, 20)),
                     duration=int(rng.integers(4, 12)), arg=0.5)
    snap = tempfile.mkdtemp(prefix="prop_chaos_")
    eng = ServeEngine(cfg, ecfg, params, injector=inj,
                      snapshot_dir=snap, snapshot_every=2)
    restarts = 0
    while True:
        try:
            rep = eng.resume() if restarts else eng.run(trace)
            break
        except EngineCrash:
            restarts += 1
            assert restarts <= 2, "one-shot crash replayed after restore"
            eng = ServeEngine.restore(cfg, ecfg, params, snap,
                                      injector=inj, snapshot_every=2)
    if inj.pending():
        # the crash step landed beyond the run's final clock — nothing to
        # recover from, but the absorbed faults must still be invisible
        assert restarts == 0
    else:
        assert restarts == 1 and rep.n_restores == 1
    for r, b in zip(rep.results, base.results):
        assert list(np.asarray(r.tokens).ravel()) == \
            list(np.asarray(b.tokens).ravel()), f"rid {r.rid} diverged"
    assert eng.kv.verify_invariants() == []
    _kv_check(eng.kv)
