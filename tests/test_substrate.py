"""Substrate tests: checkpoint atomicity/resume, data determinism,
optimizer behaviour, telemetry integration."""
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_pytree
from repro.checkpoint.store import restore_pytree
from repro.data import CifarBatches, DataConfig, TokenBatches
from repro.optim import OptimizerConfig, adamw_init, adamw_update, make_schedule
from repro.telemetry.meters import CpuProcessMeter, DramMeter, StackedMeter
from repro.telemetry.sampler import PowerSampler


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": [jnp.zeros((2,)), jnp.full((3,), 7.0)]},
            "count": jnp.asarray(5)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path, 3)
    out = restore_pytree(jax.tree.map(lambda x: x, t), tmp_path)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (10, 20, 30):
        mgr.save(t, s)
    assert mgr.latest_step() == 30
    kept = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert kept == ["step_00000020", "step_00000030"]


def test_checkpoint_uncommitted_is_ignored(tmp_path):
    t = _tree()
    save_pytree(t, tmp_path, 1)
    # simulate a crash mid-save: directory exists, no _COMMITTED marker
    fake = pathlib.Path(tmp_path) / "step_00000002"
    fake.mkdir()
    (fake / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 1
    out = restore_pytree(t, tmp_path)          # restores step 1, not 2
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, save_async=True)
    t = _tree()
    mgr.save(t, 1)
    mgr.save(t, 2)
    mgr.wait()
    assert mgr.latest_step() == 2


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------
def test_token_batches_deterministic():
    cfg = DataConfig(seed=3, vocab_size=64, seq_len=16, global_batch=4)
    a = TokenBatches(cfg).batch(7)
    b = TokenBatches(cfg).batch(7)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    np.testing.assert_array_equal(a["targets"], b["targets"])
    # pre-shift invariant: targets[t] is the token after inputs[t]
    c = TokenBatches(cfg).batch(8)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_token_batches_rank_disjoint():
    cfg = DataConfig(seed=3, vocab_size=64, seq_len=16, global_batch=4)
    r0 = TokenBatches(cfg, rank=0, world=2).batch(0)
    r1 = TokenBatches(cfg, rank=1, world=2).batch(0)
    assert r0["inputs"].shape == (2, 16)
    assert not np.array_equal(r0["inputs"], r1["inputs"])


def test_token_batches_has_learnable_structure():
    cfg = DataConfig(seed=0, vocab_size=64, seq_len=128, global_batch=8,
                     markov_strength=0.8)
    b = TokenBatches(cfg).batch(0)
    toks = np.concatenate([b["inputs"], b["targets"][:, -1:]], axis=1)
    src = TokenBatches(cfg)
    hits = (src._succ[toks[:, :-1]] == toks[:, 1:]).mean()
    assert hits > 0.5          # the Markov rule is actually present


def test_cifar_batches_separable():
    src = CifarBatches(seed=0, batch=64)
    x, y = src.batch_at(0)
    assert x.shape == (64, 32, 32, 3) and y.shape == (64,)
    # same-class images are closer than cross-class (templates dominate)
    same = cross = 0.0
    ns = nc = 0
    for i in range(20):
        for j in range(i + 1, 20):
            d = float(np.mean((x[i] - x[j]) ** 2))
            if y[i] == y[j]:
                same += d; ns += 1
            else:
                cross += d; nc += 1
    if ns and nc:
        assert same / ns < cross / nc


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=0.0, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(grads, state, params, cfg)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_clip_norm_caps_update():
    cfg = OptimizerConfig(learning_rate=1.0, clip_norm=1.0, warmup_steps=0,
                          schedule="constant", weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    _, _, m = adamw_update({"w": jnp.asarray([100.0, 0, 0])}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_schedule_shapes():
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=10,
                          total_steps=100, schedule="cosine",
                          min_lr_ratio=0.1)
    lr = make_schedule(cfg)
    assert float(lr(0)) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)


def test_sgd_momentum():
    cfg = OptimizerConfig(kind="sgd", learning_rate=0.05, momentum=0.9,
                          warmup_steps=0, schedule="constant", clip_norm=0)
    params = {"w": jnp.asarray([4.0])}
    state = adamw_init(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert abs(float(params["w"][0])) < 0.1


# --------------------------------------------------------------------------
# telemetry
# --------------------------------------------------------------------------
def test_cpu_process_meter_reports_positive_watts():
    m = CpuProcessMeter(watts_per_core=10.0, idle_w=2.0)
    _ = sum(i * i for i in range(2_000_00))     # burn some CPU
    w = m.read_watts()
    assert w >= 2.0


def test_stacked_meter_is_component_sum():
    m = StackedMeter(DramMeter(4, 16), DramMeter(2, 8))
    assert m.read_watts() == pytest.approx(24.0 + 6.0)


def test_sampler_integrates_constant_power():
    meters = {"dram": DramMeter(4, 16)}          # constant 24 W
    s = PowerSampler(meters, rate_hz=50.0)
    import time
    with s:
        time.sleep(0.25)
    rep = s.ledger.report()
    # 24 W for >=0.25 s -> >= ~5.5 J, linear in duration
    assert rep.gross_j == pytest.approx(24.0 * rep.duration_s, rel=0.05)
    assert s.n_samples >= 5
